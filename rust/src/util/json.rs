//! Minimal JSON reader/writer — enough for `artifacts/manifest.json`
//! and the result store's WAL (`store::wal`): objects, strings,
//! integers/floats, bools, null, arrays. The parser accepts the full
//! JSON escape set (including `\uXXXX` with surrogate pairs) and raw
//! UTF-8; the writer emits ASCII-only output (non-ASCII escaped as
//! `\uXXXX`) with object keys in sorted order, so rendering is
//! deterministic and a rendered value re-parses to itself.

use std::collections::BTreeMap;
use std::fmt::Write as _;

use anyhow::{bail, Result};

#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(src: &str) -> Result<Json> {
        let bytes = src.as_bytes();
        let mut pos = 0usize;
        let v = parse_value(bytes, &mut pos)?;
        skip_ws(bytes, &mut pos);
        if pos != bytes.len() {
            bail!("trailing garbage at byte {pos}");
        }
        Ok(v)
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_u64(&self) -> Option<u64> {
        match self {
            Json::Num(x) if *x >= 0.0 && x.fract() == 0.0 => Some(*x as u64),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// Serialize to a single-line JSON string. Deterministic: object
    /// keys are already sorted (`BTreeMap`), no insignificant
    /// whitespace, non-ASCII escaped. `Num` values that JSON cannot
    /// represent (NaN/±inf) render as `null` — callers that need them
    /// must encode them at the schema level (see
    /// `coordinator::jobs::RunRecord::to_json`).
    pub fn render(&self) -> String {
        let mut out = String::new();
        self.render_into(&mut out);
        out
    }

    fn render_into(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(true) => out.push_str("true"),
            Json::Bool(false) => out.push_str("false"),
            Json::Num(x) if x.is_finite() => {
                // Rust's shortest round-trip float formatting; integral
                // values print without a fractional part and re-parse
                // to the same f64.
                let _ = write!(out, "{x}");
            }
            Json::Num(_) => out.push_str("null"),
            Json::Str(s) => render_string(s, out),
            Json::Arr(v) => {
                out.push('[');
                for (i, item) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    item.render_into(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    render_string(k, out);
                    out.push(':');
                    v.render_into(out);
                }
                out.push('}');
            }
        }
    }
}

fn render_string(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            '\u{8}' => out.push_str("\\b"),
            '\u{c}' => out.push_str("\\f"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c if c.is_ascii() => out.push(c),
            c => {
                // Non-ASCII: escape so the output is pure ASCII. Chars
                // outside the BMP become a UTF-16 surrogate pair, the
                // exact form the parser reassembles.
                let cp = c as u32;
                if cp <= 0xFFFF {
                    let _ = write!(out, "\\u{cp:04x}");
                } else {
                    let v = cp - 0x10000;
                    let hi = 0xD800 + (v >> 10);
                    let lo = 0xDC00 + (v & 0x3FF);
                    let _ = write!(out, "\\u{hi:04x}\\u{lo:04x}");
                }
            }
        }
    }
    out.push('"');
}

fn skip_ws(b: &[u8], pos: &mut usize) {
    while *pos < b.len() && matches!(b[*pos], b' ' | b'\t' | b'\n' | b'\r') {
        *pos += 1;
    }
}

/// Parse the 4 hex digits of a `\uXXXX` escape (cursor on the first
/// digit); advances past them.
fn parse_hex4(b: &[u8], pos: &mut usize) -> Result<u32> {
    if *pos + 4 > b.len() {
        bail!("truncated \\u escape at byte {pos}");
    }
    let mut v = 0u32;
    for _ in 0..4 {
        let d = match b[*pos] {
            c @ b'0'..=b'9' => (c - b'0') as u32,
            c @ b'a'..=b'f' => (c - b'a' + 10) as u32,
            c @ b'A'..=b'F' => (c - b'A' + 10) as u32,
            c => bail!("bad hex digit {:?} in \\u escape", c as char),
        };
        v = (v << 4) | d;
        *pos += 1;
    }
    Ok(v)
}

fn parse_string(b: &[u8], pos: &mut usize) -> Result<String> {
    // Accumulate raw bytes so multi-byte UTF-8 in the source survives,
    // then validate once at the end.
    let mut s: Vec<u8> = Vec::new();
    loop {
        match b.get(*pos) {
            None => bail!("unterminated string"),
            Some(b'"') => {
                *pos += 1;
                return Ok(String::from_utf8(s)?);
            }
            Some(b'\\') => {
                *pos += 1;
                match b.get(*pos) {
                    Some(b'"') => s.push(b'"'),
                    Some(b'\\') => s.push(b'\\'),
                    Some(b'/') => s.push(b'/'),
                    Some(b'b') => s.push(0x08),
                    Some(b'f') => s.push(0x0C),
                    Some(b'n') => s.push(b'\n'),
                    Some(b'r') => s.push(b'\r'),
                    Some(b't') => s.push(b'\t'),
                    Some(b'u') => {
                        *pos += 1;
                        let hi = parse_hex4(b, pos)?;
                        let cp = if (0xD800..0xDC00).contains(&hi) {
                            // High surrogate: a low-surrogate escape
                            // must follow.
                            if b.get(*pos) != Some(&b'\\') || b.get(*pos + 1) != Some(&b'u') {
                                bail!("lone high surrogate \\u{hi:04x}");
                            }
                            *pos += 2;
                            let lo = parse_hex4(b, pos)?;
                            if !(0xDC00..0xE000).contains(&lo) {
                                bail!("invalid low surrogate \\u{lo:04x}");
                            }
                            0x10000 + ((hi - 0xD800) << 10) + (lo - 0xDC00)
                        } else if (0xDC00..0xE000).contains(&hi) {
                            bail!("lone low surrogate \\u{hi:04x}");
                        } else {
                            hi
                        };
                        match char::from_u32(cp) {
                            Some(c) => {
                                let mut buf = [0u8; 4];
                                s.extend_from_slice(c.encode_utf8(&mut buf).as_bytes());
                            }
                            None => bail!("invalid codepoint U+{cp:X}"),
                        }
                        // parse_hex4 already advanced past the digits.
                        continue;
                    }
                    other => bail!("unsupported escape {other:?}"),
                }
                *pos += 1;
            }
            Some(&c) => {
                s.push(c);
                *pos += 1;
            }
        }
    }
}

fn parse_value(b: &[u8], pos: &mut usize) -> Result<Json> {
    skip_ws(b, pos);
    if *pos >= b.len() {
        bail!("unexpected end of input");
    }
    match b[*pos] {
        b'{' => {
            *pos += 1;
            let mut m = BTreeMap::new();
            skip_ws(b, pos);
            if *pos < b.len() && b[*pos] == b'}' {
                *pos += 1;
                return Ok(Json::Obj(m));
            }
            loop {
                skip_ws(b, pos);
                let key = match parse_value(b, pos)? {
                    Json::Str(s) => s,
                    other => bail!("object key must be string, got {other:?}"),
                };
                skip_ws(b, pos);
                if *pos >= b.len() || b[*pos] != b':' {
                    bail!("expected ':' at byte {pos}");
                }
                *pos += 1;
                let val = parse_value(b, pos)?;
                m.insert(key, val);
                skip_ws(b, pos);
                match b.get(*pos) {
                    Some(b',') => *pos += 1,
                    Some(b'}') => {
                        *pos += 1;
                        return Ok(Json::Obj(m));
                    }
                    _ => bail!("expected ',' or '}}' at byte {pos}"),
                }
            }
        }
        b'[' => {
            *pos += 1;
            let mut v = Vec::new();
            skip_ws(b, pos);
            if *pos < b.len() && b[*pos] == b']' {
                *pos += 1;
                return Ok(Json::Arr(v));
            }
            loop {
                v.push(parse_value(b, pos)?);
                skip_ws(b, pos);
                match b.get(*pos) {
                    Some(b',') => *pos += 1,
                    Some(b']') => {
                        *pos += 1;
                        return Ok(Json::Arr(v));
                    }
                    _ => bail!("expected ',' or ']' at byte {pos}"),
                }
            }
        }
        b'"' => {
            *pos += 1;
            Ok(Json::Str(parse_string(b, pos)?))
        }
        b't' if b[*pos..].starts_with(b"true") => {
            *pos += 4;
            Ok(Json::Bool(true))
        }
        b'f' if b[*pos..].starts_with(b"false") => {
            *pos += 5;
            Ok(Json::Bool(false))
        }
        b'n' if b[*pos..].starts_with(b"null") => {
            *pos += 4;
            Ok(Json::Null)
        }
        _ => {
            let start = *pos;
            while *pos < b.len()
                && matches!(b[*pos], b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E')
            {
                *pos += 1;
            }
            let txt = std::str::from_utf8(&b[start..*pos])?;
            Ok(Json::Num(txt.parse::<f64>()?))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_manifest_shape() {
        let src = r#"{
          "adder_i4": {"file": "sop_eval_adder_i4.hlo.txt", "n": 4, "m": 3,
                        "t": 16, "b": 256, "npoints": 16},
          "mult_i8": {"file": "sop_eval_mult_i8.hlo.txt", "n": 8, "m": 8,
                       "t": 16, "b": 256, "npoints": 256}
        }"#;
        let j = Json::parse(src).unwrap();
        let adder = j.get("adder_i4").unwrap();
        assert_eq!(adder.get("n").unwrap().as_u64(), Some(4));
        assert_eq!(
            adder.get("file").unwrap().as_str(),
            Some("sop_eval_adder_i4.hlo.txt")
        );
        assert_eq!(j.as_obj().unwrap().len(), 2);
    }

    #[test]
    fn parses_scalars_arrays_escapes() {
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("-2.5e1").unwrap(), Json::Num(-25.0));
        assert_eq!(
            Json::parse(r#"[1, "a\nb", []]"#).unwrap(),
            Json::Arr(vec![
                Json::Num(1.0),
                Json::Str("a\nb".into()),
                Json::Arr(vec![])
            ])
        );
    }

    #[test]
    fn parses_full_escape_set() {
        assert_eq!(
            Json::parse(r#""\b\f\r\t\n\"\\\/""#).unwrap(),
            Json::Str("\u{8}\u{c}\r\t\n\"\\/".into())
        );
        assert_eq!(Json::parse(r#""\u0041""#).unwrap(), Json::Str("A".into()));
        assert_eq!(
            Json::parse(r#""\u00e9\u20ac""#).unwrap(),
            Json::Str("é€".into())
        );
        // Surrogate pair: U+1F600.
        assert_eq!(
            Json::parse(r#""\ud83d\ude00""#).unwrap(),
            Json::Str("\u{1F600}".into())
        );
        // Raw (unescaped) UTF-8 survives too.
        assert_eq!(Json::parse("\"héllo\"").unwrap(), Json::Str("héllo".into()));
    }

    #[test]
    fn rejects_bad_unicode_escapes() {
        assert!(Json::parse(r#""\u12""#).is_err()); // truncated
        assert!(Json::parse(r#""\uzzzz""#).is_err()); // non-hex
        assert!(Json::parse(r#""\ud83d""#).is_err()); // lone high surrogate
        assert!(Json::parse(r#""\ude00""#).is_err()); // lone low surrogate
        assert!(Json::parse(r#""\ud83d\u0041""#).is_err()); // bad pair
    }

    #[test]
    fn render_parse_round_trip() {
        let src = Json::Obj(
            [
                ("s".to_string(), Json::Str("a\n\"b\"\\é\u{1F600}\u{1}".into())),
                ("n".to_string(), Json::Num(-2.5)),
                ("i".to_string(), Json::Num(1e19)),
                ("b".to_string(), Json::Bool(true)),
                ("z".to_string(), Json::Null),
                (
                    "a".to_string(),
                    Json::Arr(vec![Json::Num(1.0), Json::Str("x".into())]),
                ),
            ]
            .into_iter()
            .collect(),
        );
        let text = src.render();
        assert!(text.is_ascii(), "renderer must emit ASCII: {text}");
        assert_eq!(Json::parse(&text).unwrap(), src);
        // Deterministic: render twice, byte-identical.
        assert_eq!(text, Json::parse(&text).unwrap().render());
    }

    #[test]
    fn render_nonfinite_as_null() {
        assert_eq!(Json::Num(f64::INFINITY).render(), "null");
        assert_eq!(Json::Num(f64::NAN).render(), "null");
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("12 34").is_err());
        assert!(Json::parse(r#"{"a" 1}"#).is_err());
    }
}

//! Minimal JSON reader — just enough for `artifacts/manifest.json`
//! (objects, strings, integers/floats, bools, null, arrays). No escapes
//! beyond `\" \\ \/ \n \t`, no unicode surrogates: the manifest is
//! machine-written by `python/compile/aot.py`.

use std::collections::BTreeMap;

use anyhow::{bail, Result};

#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(src: &str) -> Result<Json> {
        let bytes = src.as_bytes();
        let mut pos = 0usize;
        let v = parse_value(bytes, &mut pos)?;
        skip_ws(bytes, &mut pos);
        if pos != bytes.len() {
            bail!("trailing garbage at byte {pos}");
        }
        Ok(v)
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_u64(&self) -> Option<u64> {
        match self {
            Json::Num(x) if *x >= 0.0 && x.fract() == 0.0 => Some(*x as u64),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }
}

fn skip_ws(b: &[u8], pos: &mut usize) {
    while *pos < b.len() && matches!(b[*pos], b' ' | b'\t' | b'\n' | b'\r') {
        *pos += 1;
    }
}

fn parse_value(b: &[u8], pos: &mut usize) -> Result<Json> {
    skip_ws(b, pos);
    if *pos >= b.len() {
        bail!("unexpected end of input");
    }
    match b[*pos] {
        b'{' => {
            *pos += 1;
            let mut m = BTreeMap::new();
            skip_ws(b, pos);
            if *pos < b.len() && b[*pos] == b'}' {
                *pos += 1;
                return Ok(Json::Obj(m));
            }
            loop {
                skip_ws(b, pos);
                let key = match parse_value(b, pos)? {
                    Json::Str(s) => s,
                    other => bail!("object key must be string, got {other:?}"),
                };
                skip_ws(b, pos);
                if *pos >= b.len() || b[*pos] != b':' {
                    bail!("expected ':' at byte {pos}");
                }
                *pos += 1;
                let val = parse_value(b, pos)?;
                m.insert(key, val);
                skip_ws(b, pos);
                match b.get(*pos) {
                    Some(b',') => *pos += 1,
                    Some(b'}') => {
                        *pos += 1;
                        return Ok(Json::Obj(m));
                    }
                    _ => bail!("expected ',' or '}}' at byte {pos}"),
                }
            }
        }
        b'[' => {
            *pos += 1;
            let mut v = Vec::new();
            skip_ws(b, pos);
            if *pos < b.len() && b[*pos] == b']' {
                *pos += 1;
                return Ok(Json::Arr(v));
            }
            loop {
                v.push(parse_value(b, pos)?);
                skip_ws(b, pos);
                match b.get(*pos) {
                    Some(b',') => *pos += 1,
                    Some(b']') => {
                        *pos += 1;
                        return Ok(Json::Arr(v));
                    }
                    _ => bail!("expected ',' or ']' at byte {pos}"),
                }
            }
        }
        b'"' => {
            *pos += 1;
            let mut s = String::new();
            loop {
                match b.get(*pos) {
                    None => bail!("unterminated string"),
                    Some(b'"') => {
                        *pos += 1;
                        return Ok(Json::Str(s));
                    }
                    Some(b'\\') => {
                        *pos += 1;
                        match b.get(*pos) {
                            Some(b'"') => s.push('"'),
                            Some(b'\\') => s.push('\\'),
                            Some(b'/') => s.push('/'),
                            Some(b'n') => s.push('\n'),
                            Some(b't') => s.push('\t'),
                            other => bail!("unsupported escape {other:?}"),
                        }
                        *pos += 1;
                    }
                    Some(&c) => {
                        s.push(c as char);
                        *pos += 1;
                    }
                }
            }
        }
        b't' if b[*pos..].starts_with(b"true") => {
            *pos += 4;
            Ok(Json::Bool(true))
        }
        b'f' if b[*pos..].starts_with(b"false") => {
            *pos += 5;
            Ok(Json::Bool(false))
        }
        b'n' if b[*pos..].starts_with(b"null") => {
            *pos += 4;
            Ok(Json::Null)
        }
        _ => {
            let start = *pos;
            while *pos < b.len()
                && matches!(b[*pos], b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E')
            {
                *pos += 1;
            }
            let txt = std::str::from_utf8(&b[start..*pos])?;
            Ok(Json::Num(txt.parse::<f64>()?))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_manifest_shape() {
        let src = r#"{
          "adder_i4": {"file": "sop_eval_adder_i4.hlo.txt", "n": 4, "m": 3,
                        "t": 16, "b": 256, "npoints": 16},
          "mult_i8": {"file": "sop_eval_mult_i8.hlo.txt", "n": 8, "m": 8,
                       "t": 16, "b": 256, "npoints": 256}
        }"#;
        let j = Json::parse(src).unwrap();
        let adder = j.get("adder_i4").unwrap();
        assert_eq!(adder.get("n").unwrap().as_u64(), Some(4));
        assert_eq!(
            adder.get("file").unwrap().as_str(),
            Some("sop_eval_adder_i4.hlo.txt")
        );
        assert_eq!(j.as_obj().unwrap().len(), 2);
    }

    #[test]
    fn parses_scalars_arrays_escapes() {
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("-2.5e1").unwrap(), Json::Num(-25.0));
        assert_eq!(
            Json::parse(r#"[1, "a\nb", []]"#).unwrap(),
            Json::Arr(vec![
                Json::Num(1.0),
                Json::Str("a\nb".into()),
                Json::Arr(vec![])
            ])
        );
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("12 34").is_err());
        assert!(Json::parse(r#"{"a" 1}"#).is_err());
    }
}

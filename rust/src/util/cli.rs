//! Tiny argv parser: `--key value` / `--flag` options after a positional
//! subcommand. Replaces `clap` in the offline build environment.

use std::collections::BTreeMap;

use anyhow::{bail, Result};

#[derive(Debug, Default, Clone)]
pub struct Args {
    pub positional: Vec<String>,
    pub options: BTreeMap<String, String>,
    pub flags: Vec<String>,
}

impl Args {
    /// Parse raw argv (without the program name). Tokens beginning with
    /// `--` become options if followed by a non-`--` token, flags
    /// otherwise.
    pub fn parse<I: IntoIterator<Item = String>>(argv: I) -> Args {
        let toks: Vec<String> = argv.into_iter().collect();
        let mut out = Args::default();
        let mut i = 0usize;
        while i < toks.len() {
            if let Some(name) = toks[i].strip_prefix("--") {
                if i + 1 < toks.len() && !toks[i + 1].starts_with("--") {
                    out.options.insert(name.to_string(), toks[i + 1].clone());
                    i += 2;
                } else {
                    out.flags.push(name.to_string());
                    i += 1;
                }
            } else {
                out.positional.push(toks[i].clone());
                i += 1;
            }
        }
        out
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.options.get(key).map(String::as_str)
    }

    pub fn get_or(&self, key: &str, default: &str) -> String {
        self.get(key).unwrap_or(default).to_string()
    }

    pub fn get_u64(&self, key: &str) -> Result<Option<u64>> {
        match self.get(key) {
            None => Ok(None),
            Some(v) => match v.parse() {
                Ok(x) => Ok(Some(x)),
                Err(_) => bail!("--{key} expects an integer, got '{v}'"),
            },
        }
    }

    pub fn get_usize_or(&self, key: &str, default: usize) -> Result<usize> {
        Ok(self.get_u64(key)?.map(|x| x as usize).unwrap_or(default))
    }

    pub fn has_flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(str::to_string))
    }

    #[test]
    fn mixed_parsing() {
        let a = parse("sweep --bench adder_i4 --et 2 out.csv --verbose");
        assert_eq!(a.positional, vec!["sweep", "out.csv"]);
        assert_eq!(a.get("bench"), Some("adder_i4"));
        assert_eq!(a.get_u64("et").unwrap(), Some(2));
        assert!(a.has_flag("verbose"));
    }

    #[test]
    fn bad_integer_errors() {
        let a = parse("--et banana");
        assert!(a.get_u64("et").is_err());
    }

    #[test]
    fn defaults() {
        let a = parse("cmd");
        assert_eq!(a.get_or("missing", "x"), "x");
        assert_eq!(a.get_usize_or("n", 7).unwrap(), 7);
        assert!(!a.has_flag("q"));
    }
}

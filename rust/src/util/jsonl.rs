//! The line-delimited-JSON wire discipline shared by every TCP
//! endpoint in the tree (`serve` and `dist`): one JSON value per line,
//! a hard cap on line length, a per-connection writer thread so
//! concurrent producers never interleave bytes on a socket, and the
//! structured `{"ok":false,"error":...}` failure shape. The framing
//! exists exactly once, so the two protocols cannot drift apart.
//!
//! Framing rules:
//!
//! * One request or response per `\n`-terminated line; blank lines are
//!   legal no-ops.
//! * A line longer than [`MAX_LINE_BYTES`] without its newline cannot
//!   be re-framed (the reader has no way to find the next boundary),
//!   so the connection must close after one structured error.
//! * Failures render as `{"id":N,"ok":false,"error":"..."}` — peers
//!   without request ids send 0 — and never kill the connection except
//!   for the oversize case above.

use std::collections::BTreeMap;
use std::io::{BufRead, Read, Write};
use std::sync::mpsc::Receiver;
use std::thread::JoinHandle;

use crate::util::Json;

/// Hard cap on one wire line; longer lines get an error response
/// instead of unbounded buffering.
pub const MAX_LINE_BYTES: usize = 64 * 1024;

/// One framed read off a line-delimited-JSON stream.
#[derive(Debug, PartialEq, Eq)]
pub enum LineRead {
    /// A complete line, whitespace-trimmed (may be empty).
    Line(String),
    /// The peer closed the stream, or an I/O error ended it.
    Eof,
    /// The line exceeded [`MAX_LINE_BYTES`] before its newline arrived;
    /// the stream cannot be re-framed and must close.
    Oversized,
}

/// Read one capped line. The `take` guard bounds how much one line may
/// buffer: a well-formed line of exactly `MAX_LINE_BYTES` plus its
/// newline still fits, anything longer surfaces as
/// [`LineRead::Oversized`]. A final EOF-terminated line that lost its
/// newline but fits the cap is returned as a normal line.
pub fn read_line<R: BufRead>(reader: &mut R) -> LineRead {
    let mut line = String::new();
    // +2 so a MAX-byte line still fits with its (CR)LF; the cap is
    // then enforced on the content with the line ending stripped, so
    // the boundary cases (MAX+1 content plus newline) cannot slip
    // through the "ends with newline" shape.
    let mut limited = reader.by_ref().take(MAX_LINE_BYTES as u64 + 2);
    match limited.read_line(&mut line) {
        Ok(0) | Err(_) => LineRead::Eof,
        Ok(_) => {
            let content = line.strip_suffix('\n').unwrap_or(&line);
            let content = content.strip_suffix('\r').unwrap_or(content);
            if content.len() > MAX_LINE_BYTES {
                LineRead::Oversized
            } else {
                LineRead::Line(content.trim().to_string())
            }
        }
    }
}

/// Write one line (appending the newline) in two `write_all`s — the
/// client half of the discipline for strict request/response peers
/// that own their socket exclusively.
pub fn send_line<W: Write>(w: &mut W, line: &str) -> std::io::Result<()> {
    w.write_all(line.as_bytes())?;
    w.write_all(b"\n")
}

/// Spawn the per-connection writer thread: drains `rx` onto `sink`
/// until every `Sender` clone is gone (reader thread plus any
/// in-flight work items or `watch` samplers), so concurrent producers
/// never interleave bytes on a shared socket.
///
/// **Teardown contract.** When the peer dies (a write fails) or the
/// sink panics, the thread exits and `rx` is dropped with it — from
/// that moment every producer's `Sender::send` returns `Err`, which is
/// how long-lived producers (the serve `watch` sampler in particular)
/// learn the subscriber is gone and stop. Panics from the sink are
/// contained here so `JoinHandle::join` on the connection path never
/// sees one; nothing is drained after exit, because a silently
/// draining receiver would keep producers alive forever.
pub fn spawn_writer<W: Write + Send + 'static>(
    mut sink: W,
    rx: Receiver<String>,
) -> JoinHandle<()> {
    std::thread::spawn(move || {
        // `rx` stays owned by this outer closure, so it is dropped (and
        // producers start seeing send errors) even on a panic exit.
        let _ = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            while let Ok(line) = rx.recv() {
                if send_line(&mut sink, &line).is_err() {
                    break;
                }
            }
        }));
    })
}

/// Render the structured failure line `{"error":...,"id":N,"ok":false}`
/// (sorted keys, ASCII — the `Json::render` guarantees). Peers whose
/// protocol has no request ids pass 0.
pub fn error_line(id: u64, error: &str) -> String {
    let mut m = BTreeMap::new();
    m.insert("id".to_string(), Json::Num(id as f64));
    m.insert("ok".to_string(), Json::Bool(false));
    m.insert("error".to_string(), Json::Str(error.to_string()));
    Json::Obj(m).render()
}

/// Best-effort `"id"` recovery from a line that failed full parsing,
/// so even malformed-request errors can be matched by pipelined
/// clients. Lines with no recoverable id report 0.
pub fn recover_id(line: &str) -> u64 {
    Json::parse(line)
        .ok()
        .and_then(|j| j.get("id").and_then(Json::as_u64))
        .unwrap_or(0)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::BufReader;

    fn reader(s: &str) -> BufReader<&[u8]> {
        BufReader::new(s.as_bytes())
    }

    #[test]
    fn reads_lines_blanks_and_eof() {
        let mut r = reader("{\"a\":1}\n\n  {\"b\":2}  \nno newline tail");
        assert_eq!(read_line(&mut r), LineRead::Line("{\"a\":1}".to_string()));
        assert_eq!(read_line(&mut r), LineRead::Line(String::new()));
        assert_eq!(read_line(&mut r), LineRead::Line("{\"b\":2}".to_string()));
        // An EOF-terminated line under the cap is still a line...
        assert_eq!(read_line(&mut r), LineRead::Line("no newline tail".to_string()));
        // ...and then the stream is over.
        assert_eq!(read_line(&mut r), LineRead::Eof);
    }

    #[test]
    fn oversized_line_cannot_be_reframed() {
        let huge = "x".repeat(MAX_LINE_BYTES + 1);
        let mut r = reader(&huge);
        assert_eq!(read_line(&mut r), LineRead::Oversized);
        // Exactly at the cap (with newline) is fine — also with CRLF.
        for ending in ["\n", "\r\n"] {
            let fits = format!("{}{ending}", "y".repeat(MAX_LINE_BYTES));
            let mut r = reader(&fits);
            assert!(
                matches!(read_line(&mut r), LineRead::Line(l) if l.len() == MAX_LINE_BYTES)
            );
        }
        // One content byte over the cap is Oversized even when its
        // newline arrived within the read limit (the boundary shape a
        // tail-length check would miss).
        let boundary = format!("{}\n", "z".repeat(MAX_LINE_BYTES + 1));
        let mut r = reader(&boundary);
        assert_eq!(read_line(&mut r), LineRead::Oversized);
    }

    #[test]
    fn writer_thread_serializes_lines() {
        let (tx, rx) = std::sync::mpsc::channel::<String>();
        let buf: Vec<u8> = Vec::new();
        let shared = std::sync::Arc::new(std::sync::Mutex::new(buf));
        struct Sink(std::sync::Arc<std::sync::Mutex<Vec<u8>>>);
        impl Write for Sink {
            fn write(&mut self, b: &[u8]) -> std::io::Result<usize> {
                self.0.lock().unwrap().extend_from_slice(b);
                Ok(b.len())
            }
            fn flush(&mut self) -> std::io::Result<()> {
                Ok(())
            }
        }
        let h = spawn_writer(Sink(shared.clone()), rx);
        tx.send("one".to_string()).unwrap();
        tx.send("two".to_string()).unwrap();
        drop(tx);
        h.join().unwrap();
        assert_eq!(&*shared.lock().unwrap(), b"one\ntwo\n");
    }

    /// The watch-teardown contract: a sink that dies mid-stream ends
    /// the writer thread, and from then on every producer's `send`
    /// fails — the signal long-lived samplers stop on.
    #[test]
    fn writer_death_propagates_to_producers() {
        struct FailAfter(usize);
        impl Write for FailAfter {
            fn write(&mut self, b: &[u8]) -> std::io::Result<usize> {
                if self.0 == 0 {
                    return Err(std::io::Error::new(
                        std::io::ErrorKind::BrokenPipe,
                        "peer gone",
                    ));
                }
                self.0 -= 1;
                Ok(b.len())
            }
            fn flush(&mut self) -> std::io::Result<()> {
                Ok(())
            }
        }
        let (tx, rx) = std::sync::mpsc::channel::<String>();
        // Two writes per line (content + newline): allow exactly one line.
        let h = spawn_writer(FailAfter(2), rx);
        tx.send("ok".to_string()).unwrap();
        tx.send("dies".to_string()).unwrap();
        h.join().expect("writer thread exits cleanly, not by panic");
        assert!(
            tx.send("after death".to_string()).is_err(),
            "rx dropped with the thread => producers see Err"
        );
    }

    /// A panicking sink must not poison the connection path: join()
    /// still returns Ok, and producers still get the Err signal.
    #[test]
    fn writer_panic_is_contained() {
        struct PanicSink;
        impl Write for PanicSink {
            fn write(&mut self, _: &[u8]) -> std::io::Result<usize> {
                panic!("sink exploded");
            }
            fn flush(&mut self) -> std::io::Result<()> {
                Ok(())
            }
        }
        let (tx, rx) = std::sync::mpsc::channel::<String>();
        let h = spawn_writer(PanicSink, rx);
        tx.send("boom".to_string()).unwrap();
        h.join().expect("panic contained inside the writer thread");
        assert!(tx.send("later".to_string()).is_err());
    }

    #[test]
    fn error_shape_and_id_recovery() {
        let line = error_line(7, "bad thing");
        assert_eq!(line, "{\"error\":\"bad thing\",\"id\":7,\"ok\":false}");
        assert_eq!(recover_id(&line), 7);
        assert_eq!(recover_id("{\"id\":42,\"type\":\"junk\"}"), 42);
        assert_eq!(recover_id("garbage"), 0);
    }
}

//! xoshiro256** PRNG — deterministic, seedable, dependency-free.
//!
//! Used for random sound approximations (Fig. 4's red-circle baseline),
//! workload generation and the in-tree property tests. Not cryptographic.

#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Seed via SplitMix64 so nearby seeds diverge immediately.
    pub fn seed_from(seed: u64) -> Self {
        let mut sm = seed;
        let mut next = move || {
            sm = sm.wrapping_add(0x9E3779B97F4A7C15);
            let mut z = sm;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
            z ^ (z >> 31)
        };
        Rng { s: [next(), next(), next(), next()] }
    }

    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, bound)` via Lemire's method (bound > 0).
    pub fn below(&mut self, bound: u64) -> u64 {
        assert!(bound > 0);
        ((self.next_u64() as u128 * bound as u128) >> 64) as u64
    }

    pub fn usize_below(&mut self, bound: usize) -> usize {
        self.below(bound as u64) as usize
    }

    /// Bernoulli with probability `p`.
    pub fn chance(&mut self, p: f64) -> bool {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64) < p
    }

    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.usize_below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Derive an independent stream (for per-worker seeding).
    pub fn split(&mut self) -> Rng {
        Rng::seed_from(self.next_u64())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_per_seed() {
        let mut a = Rng::seed_from(7);
        let mut b = Rng::seed_from(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::seed_from(1);
        let mut b = Rng::seed_from(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn below_respects_bound() {
        let mut r = Rng::seed_from(42);
        for _ in 0..10_000 {
            assert!(r.below(7) < 7);
        }
    }

    #[test]
    fn chance_rough_frequency() {
        let mut r = Rng::seed_from(3);
        let hits = (0..100_000).filter(|_| r.chance(0.25)).count();
        assert!((20_000..30_000).contains(&hits), "hits={hits}");
    }

    #[test]
    fn shuffle_is_a_permutation() {
        let mut r = Rng::seed_from(9);
        let mut xs: Vec<u32> = (0..50).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<u32>>());
    }

    #[test]
    fn split_streams_are_independent() {
        let mut a = Rng::seed_from(5);
        let mut b = a.split();
        let av: Vec<u64> = (0..10).map(|_| a.next_u64()).collect();
        let bv: Vec<u64> = (0..10).map(|_| b.next_u64()).collect();
        assert_ne!(av, bv);
    }
}

//! Small in-tree utilities replacing crates the offline build environment
//! does not provide: a splittable PRNG (`rng`), a minimal JSON
//! reader/writer for the artifact manifest and the result-store WAL
//! (`json`), a tiny argv parser (`cli`), and the line-delimited-JSON
//! wire discipline shared by the TCP endpoints (`jsonl`).

pub mod cli;
pub mod json;
pub mod jsonl;
pub mod rng;

pub use json::Json;
pub use rng::Rng;

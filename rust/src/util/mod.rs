//! Small in-tree utilities replacing crates the offline build environment
//! does not provide: a splittable PRNG (`rng`), a minimal JSON
//! reader/writer for the artifact manifest and the result-store WAL
//! (`json`), and a tiny argv parser (`cli`).

pub mod cli;
pub mod json;
pub mod rng;

pub use json::Json;
pub use rng::Rng;

//! A concrete SOP template instantiation — the object the search hands
//! around: evaluated exhaustively (rust or PJRT), extracted to a netlist
//! for synthesis, and measured for the proxy metrics of §III.

use crate::circuit::netlist::{GateKind, Netlist, NodeId};
use crate::util::Rng;

/// Parameters of a (possibly shared) sum-of-products template over `n`
/// inputs, `m` outputs and a pool of `t` products. The nonshared XPAT
/// template is the special case where `out_sel` is block-diagonal.
#[derive(Debug, Clone, PartialEq)]
pub struct SopParams {
    pub n: usize,
    pub m: usize,
    pub t: usize,
    /// `[t][n]` flattened: literal j participates in product k.
    pub use_mask: Vec<bool>,
    /// `[t][n]` flattened: literal appears negated (meaningful when used).
    pub neg_mask: Vec<bool>,
    /// `[m][t]` flattened: product k feeds output i.
    pub out_sel: Vec<bool>,
    /// Output i is the constant 1 (the `∨ ⊤` term of eq. 2).
    pub out_const: Vec<bool>,
}

impl SopParams {
    pub fn empty(n: usize, m: usize, t: usize) -> Self {
        SopParams {
            n,
            m,
            t,
            use_mask: vec![false; t * n],
            neg_mask: vec![false; t * n],
            out_sel: vec![false; m * t],
            out_const: vec![false; m],
        }
    }

    #[inline]
    pub fn uses(&self, k: usize, j: usize) -> bool {
        self.use_mask[k * self.n + j]
    }

    #[inline]
    pub fn negated(&self, k: usize, j: usize) -> bool {
        self.neg_mask[k * self.n + j]
    }

    #[inline]
    pub fn selects(&self, i: usize, k: usize) -> bool {
        self.out_sel[i * self.t + k]
    }

    /// Product k's value at input point `x` (empty product = 1).
    pub fn product_at(&self, k: usize, x: usize) -> bool {
        (0..self.n).all(|j| {
            !self.uses(k, j) || (((x >> j) & 1 == 1) ^ self.negated(k, j))
        })
    }

    /// Output value (LSB-first integer) at input point `x`.
    pub fn value_at(&self, x: usize) -> u64 {
        let prods: Vec<bool> = (0..self.t).map(|k| self.product_at(k, x)).collect();
        (0..self.m).fold(0u64, |acc, i| {
            let bit = self.out_const[i]
                || (0..self.t).any(|k| self.selects(i, k) && prods[k]);
            acc | ((bit as u64) << i)
        })
    }

    /// All output values — the slow direct-semantics oracle; the fast
    /// bit-parallel version lives in [`crate::evaluator`].
    pub fn output_values(&self) -> Vec<u64> {
        (0..1usize << self.n).map(|x| self.value_at(x)).collect()
    }

    // ---- §III proxy metrics ------------------------------------------

    /// Products-in-total: pool products referenced by at least one sum.
    pub fn pit(&self) -> usize {
        (0..self.t)
            .filter(|&k| (0..self.m).any(|i| self.selects(i, k)))
            .count()
    }

    /// Inputs-to-sums: total product→sum connections.
    pub fn its(&self) -> usize {
        self.out_sel.iter().filter(|&&b| b).count()
    }

    /// Max literals-per-product over *used* products (XPAT's LPP).
    pub fn lpp(&self) -> usize {
        (0..self.t)
            .filter(|&k| (0..self.m).any(|i| self.selects(i, k)))
            .map(|k| (0..self.n).filter(|&j| self.uses(k, j)).count())
            .max()
            .unwrap_or(0)
    }

    /// Max products-per-output (XPAT's PPO).
    pub fn ppo(&self) -> usize {
        (0..self.m)
            .map(|i| (0..self.t).filter(|&k| self.selects(i, k)).count())
            .max()
            .unwrap_or(0)
    }

    /// Extract the instantiated template as a gate-level netlist (the
    /// circuit that goes to synthesis). Unused products are skipped;
    /// literals materialise one inverter per input, shared.
    pub fn to_netlist(&self, name: &str) -> Netlist {
        let mut nl = Netlist::new(name);
        let ins: Vec<NodeId> = (0..self.n).map(|_| nl.add_input()).collect();
        let mut invs: Vec<Option<NodeId>> = vec![None; self.n];
        let used: Vec<bool> = (0..self.t)
            .map(|k| (0..self.m).any(|i| self.selects(i, k)))
            .collect();

        let mut const0: Option<NodeId> = None;
        let mut const1: Option<NodeId> = None;
        let mut prod_node: Vec<Option<NodeId>> = vec![None; self.t];
        for k in 0..self.t {
            if !used[k] {
                continue;
            }
            let mut lits: Vec<NodeId> = Vec::new();
            for j in 0..self.n {
                if !self.uses(k, j) {
                    continue;
                }
                if self.negated(k, j) {
                    let inv = *invs[j]
                        .get_or_insert_with(|| nl.push(GateKind::Not, vec![ins[j]]));
                    lits.push(inv);
                } else {
                    lits.push(ins[j]);
                }
            }
            prod_node[k] = Some(match lits.len() {
                0 => *const1.get_or_insert_with(|| nl.push(GateKind::Const1, vec![])),
                1 => lits[0],
                _ => nl.push(GateKind::And, lits),
            });
        }

        let mut outs = Vec::with_capacity(self.m);
        for i in 0..self.m {
            if self.out_const[i] {
                outs.push(*const1.get_or_insert_with(|| nl.push(GateKind::Const1, vec![])));
                continue;
            }
            let terms: Vec<NodeId> = (0..self.t)
                .filter(|&k| self.selects(i, k))
                .map(|k| prod_node[k].expect("selected product must be built"))
                .collect();
            outs.push(match terms.len() {
                0 => *const0.get_or_insert_with(|| nl.push(GateKind::Const0, vec![])),
                1 => terms[0],
                _ => nl.push(GateKind::Or, terms),
            });
        }
        nl.set_outputs(outs);
        nl
    }

    /// Random instantiation (for the Fig. 4 random baseline and tests).
    /// `lit_density` is the chance a literal is used in a product,
    /// `sel_density` the chance a product feeds an output.
    pub fn random(rng: &mut Rng, n: usize, m: usize, t: usize,
                  lit_density: f64, sel_density: f64) -> Self {
        let mut p = SopParams::empty(n, m, t);
        for v in p.use_mask.iter_mut() {
            *v = rng.chance(lit_density);
        }
        for v in p.neg_mask.iter_mut() {
            *v = rng.chance(0.5);
        }
        for v in p.out_sel.iter_mut() {
            *v = rng.chance(sel_density);
        }
        for v in p.out_const.iter_mut() {
            *v = rng.chance(0.05);
        }
        p
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::circuit::sim::TruthTables;

    /// out0 = in0 & ~in1, out1 = in0 & ~in1 | in2 (product shared).
    fn sample() -> SopParams {
        let mut p = SopParams::empty(3, 2, 2);
        p.use_mask[0] = true; // prod0: in0
        p.use_mask[1] = true; // prod0: in1
        p.neg_mask[1] = true; // ... negated
        p.use_mask[3 + 2] = true; // prod1: in2
        p.out_sel[0] = true; // out0 <- prod0
        p.out_sel[2] = true; // out1 <- prod0
        p.out_sel[3] = true; // out1 <- prod1
        p
    }

    #[test]
    fn direct_semantics() {
        let p = sample();
        for x in 0..8usize {
            let in0 = x & 1 == 1;
            let in1 = (x >> 1) & 1 == 1;
            let in2 = (x >> 2) & 1 == 1;
            let prod = in0 && !in1;
            let want = (prod as u64) | (((prod || in2) as u64) << 1);
            assert_eq!(p.value_at(x), want, "x={x}");
        }
    }

    #[test]
    fn proxies() {
        let p = sample();
        assert_eq!(p.pit(), 2);
        assert_eq!(p.its(), 3);
        assert_eq!(p.lpp(), 2);
        assert_eq!(p.ppo(), 2);
    }

    #[test]
    fn netlist_extraction_matches_direct_eval() {
        let p = sample();
        let nl = p.to_netlist("sample");
        assert!(nl.validate().is_ok());
        let tt = TruthTables::simulate(&nl);
        assert_eq!(tt.output_values(&nl), p.output_values());
    }

    #[test]
    fn empty_template_outputs_zero() {
        let p = SopParams::empty(3, 2, 4);
        assert!(p.output_values().iter().all(|&v| v == 0));
        let nl = p.to_netlist("zero");
        let tt = TruthTables::simulate(&nl);
        assert!(tt.output_values(&nl).iter().all(|&v| v == 0));
    }

    #[test]
    fn const_output_and_empty_product() {
        let mut p = SopParams::empty(2, 2, 1);
        p.out_const[0] = true; // out0 = 1
        p.out_sel[1 * 1 + 0] = true; // out1 <- prod0 (empty product = 1)
        assert!(p.output_values().iter().all(|&v| v == 3));
        let nl = p.to_netlist("consts");
        let tt = TruthTables::simulate(&nl);
        assert!(tt.output_values(&nl).iter().all(|&v| v == 3));
    }

    #[test]
    fn random_extraction_agrees_for_many_seeds() {
        for seed in 0..30u64 {
            let mut rng = Rng::seed_from(seed);
            let p = SopParams::random(&mut rng, 4, 3, 6, 0.4, 0.3);
            let nl = p.to_netlist("rnd");
            let tt = TruthTables::simulate(&nl);
            assert_eq!(tt.output_values(&nl), p.output_values(), "seed={seed}");
        }
    }

    #[test]
    fn unused_products_do_not_appear_in_netlist() {
        let mut p = SopParams::empty(3, 1, 5);
        // Fill literals of all products but select none.
        for v in p.use_mask.iter_mut() {
            *v = true;
        }
        let nl = p.to_netlist("dead");
        assert_eq!(nl.n_logic_gates(), 0, "{:?}", nl.gates);
    }
}

//! The ∀-expanded error miter (paper Fig. 1).
//!
//! The paper poses `∃p ∀i: dist(i, p) <= ET` to an SMT solver. At the
//! benchmark sizes (n <= 8 inputs) the universal quantifier is expanded:
//! one copy of the template-evaluation logic per input point, all copies
//! sharing the parameter variables `p`, and per-point interval
//! constraints `V(x) ∈ [E(x)-ET, E(x)+ET]` (the exact value `E(x)` is a
//! constant obtained by simulating the exact circuit — `map`/`dist` of
//! the paper collapse to constant interval checks). The resulting CNF is
//! equisatisfiable with the paper's query and complete at these sizes.
//!
//! Restrictions (§III) are *assumption literals* over totalizer counters,
//! so one encoded miter serves the whole lattice search:
//! * SHARED:   PIT (products referenced anywhere), ITS (product→sum edges)
//! * XPAT:     LPP (literals per product), PPO (products per output)
//!
//! Both miters additionally carry gate-count and inverter-count proxy
//! counters so the search can greedily minimise the synthesised-area
//! drivers *within* a SAT cell (`solve_minimized_deadline`).

use std::time::Instant;

use crate::sat::{Lit, SatResult};
use crate::smt::cardinality::BoundedCounter;
use crate::smt::cnf::CnfBuilder;
use crate::smt::compare::value_in_range;

use super::params::SopParams;
use super::SolveOutcome;

/// Parameter literals shared by both templates.
#[derive(Clone)]
pub struct ParamVars {
    pub n: usize,
    pub m: usize,
    pub t: usize,
    pub use_lits: Vec<Lit>,   // [t][n]
    pub neg_lits: Vec<Lit>,   // [t][n]
    pub sel_lits: Vec<Lit>,   // [m][t]
    pub const_lits: Vec<Lit>, // [m]
}

impl ParamVars {
    fn new(b: &mut CnfBuilder, n: usize, m: usize, t: usize) -> Self {
        ParamVars {
            n,
            m,
            t,
            use_lits: (0..t * n).map(|_| b.new_lit()).collect(),
            neg_lits: (0..t * n).map(|_| b.new_lit()).collect(),
            sel_lits: (0..m * t).map(|_| b.new_lit()).collect(),
            const_lits: (0..m).map(|_| b.new_lit()).collect(),
        }
    }

    /// Read a model back into a concrete instantiation.
    fn extract(&self, b: &CnfBuilder) -> SopParams {
        let mv = |l: Lit| b.solver.model_value(l);
        SopParams {
            n: self.n,
            m: self.m,
            t: self.t,
            use_mask: self.use_lits.iter().map(|&l| mv(l)).collect(),
            neg_mask: self.neg_lits.iter().map(|&l| mv(l)).collect(),
            out_sel: self.sel_lits.iter().map(|&l| mv(l)).collect(),
            out_const: self.const_lits.iter().map(|&l| mv(l)).collect(),
        }
    }

    /// Clause forbidding a specific parameter assignment — enumeration
    /// of further satisfying assignments (Fig. 4 shows several per
    /// method). Built from the extracted params (not the solver model,
    /// which a later UNSAT minimisation probe would have cleared).
    fn blocking_clause(&self, p: &SopParams) -> Vec<Lit> {
        let pick = |l: Lit, v: bool| if v { !l } else { l };
        self.sel_lits
            .iter()
            .zip(&p.out_sel)
            .map(|(&l, &v)| pick(l, v))
            .chain(self.const_lits.iter().zip(&p.out_const).map(|(&l, &v)| pick(l, v)))
            .chain(self.use_lits.iter().zip(&p.use_mask).map(|(&l, &v)| pick(l, v)))
            .chain(self.neg_lits.iter().zip(&p.neg_mask).map(|(&l, &v)| pick(l, v)))
            .collect()
    }
}

/// Shared encoding core: template evaluation copies per input point.
///
/// Per product k and input j, two derived literals absorb the input
/// constant: `a = ¬use ∨ ¬neg` (literal value when in_j = 1) and
/// `b = ¬use ∨ neg` (when in_j = 0). Product copy P_{k,x} is then a plain
/// conjunction of single literals — one Tseitin AND per point.
fn encode_products(
    b: &mut CnfBuilder,
    p: &ParamVars,
    npoints: usize,
) -> Vec<Vec<Lit>> {
    let (n, t) = (p.n, p.t);
    let mut a_lit = vec![Lit(0); t * n];
    let mut b_lit = vec![Lit(0); t * n];
    for k in 0..t {
        for j in 0..n {
            let u = p.use_lits[k * n + j];
            let g = p.neg_lits[k * n + j];
            let a = b.new_lit();
            // a <-> (!u | !g)
            b.add_clause(&[!a, !u, !g]);
            b.add_clause(&[a, u]);
            b.add_clause(&[a, g]);
            let bb = b.new_lit();
            // bb <-> (!u | g)
            b.add_clause(&[!bb, !u, g]);
            b.add_clause(&[bb, u]);
            b.add_clause(&[bb, !g]);
            a_lit[k * n + j] = a;
            b_lit[k * n + j] = bb;
        }
    }
    let mut prods: Vec<Vec<Lit>> = vec![vec![Lit(0); npoints]; t];
    for (k, row) in prods.iter_mut().enumerate() {
        for (x, slot) in row.iter_mut().enumerate() {
            let conj: Vec<Lit> = (0..n)
                .map(|j| {
                    if (x >> j) & 1 == 1 {
                        a_lit[k * n + j]
                    } else {
                        b_lit[k * n + j]
                    }
                })
                .collect();
            *slot = b.and(&conj);
        }
    }
    prods
}

/// Per-point output bits and interval constraints.
fn encode_outputs_and_distance(
    b: &mut CnfBuilder,
    p: &ParamVars,
    prods: &[Vec<Lit>],
    exact: &[u64],
    et: u64,
) {
    let (m, t) = (p.m, p.t);
    let npoints = exact.len();
    let top = (1u64 << m) - 1;
    for x in 0..npoints {
        let mut bits = Vec::with_capacity(m);
        for i in 0..m {
            // s_{i,k,x} <-> sel_ik & P_kx ; bit = const_i | OR_k s
            let mut terms: Vec<Lit> = Vec::with_capacity(t + 1);
            terms.push(p.const_lits[i]);
            for (k, prod_row) in prods.iter().enumerate() {
                let s = b.and(&[p.sel_lits[i * t + k], prod_row[x]]);
                terms.push(s);
            }
            bits.push(b.or(&terms));
        }
        let lo = exact[x].saturating_sub(et);
        let hi = (exact[x] + et).min(top);
        value_in_range(b, &bits, lo, hi);
    }
}

/// Gate-count + inverter-count proxy counters over the parameter vars.
///
/// A product with L literals costs L-1 AND2s and a sum with S inputs
/// costs S-1 OR2s, so count every literal beyond the first of its
/// product and every selection beyond the first of its output — Σ is
/// exactly the 2-input gate count of the extracted SOP netlist.
/// Negated literals cost an inverter each, positive ones are free wires.
/// Used by both templates (for the nonshared one the hard-wired-false
/// cross-block selection literals simply never count).
fn encode_gate_proxy(
    b: &mut CnfBuilder,
    params: &ParamVars,
) -> (BoundedCounter, BoundedCounter) {
    let (n, m, t) = (params.n, params.m, params.t);
    let mut gate_bits: Vec<Lit> = Vec::new();
    for k in 0..t {
        let mut prefix: Option<Lit> = None;
        for j in 0..n {
            let u = params.use_lits[k * n + j];
            if let Some(pf) = prefix {
                gate_bits.push(b.and(&[u, pf]));
                let np = b.new_lit();
                b.define_or2(np, pf, u);
                prefix = Some(np);
            } else {
                prefix = Some(u);
            }
        }
    }
    for i in 0..m {
        let mut prefix: Option<Lit> = None;
        for k in 0..t {
            let sl = params.sel_lits[i * t + k];
            if let Some(pf) = prefix {
                gate_bits.push(b.and(&[sl, pf]));
                let np = b.new_lit();
                b.define_or2(np, pf, sl);
                prefix = Some(np);
            } else {
                prefix = Some(sl);
            }
        }
    }
    let gates = BoundedCounter::new(b, &gate_bits);
    let negs = BoundedCounter::new(b, &params.neg_lits.clone());
    (gates, negs)
}

/// One `solve_limited` call mapped onto the three-way outcome.
fn solve_with(
    b: &mut CnfBuilder,
    params: &ParamVars,
    assumptions: &[Lit],
) -> SolveOutcome {
    match b.solver.solve_limited(assumptions) {
        Some(SatResult::Sat) => SolveOutcome::Sat(params.extract(b)),
        Some(SatResult::Unsat) => SolveOutcome::Unsat,
        None => SolveOutcome::Budget,
    }
}

/// Greedy within-cell minimisation shared by both templates: descend on
/// the gate-count proxy, then on inverters holding the achieved gate
/// optimum. Every probe is assumption-only, so the miter stays reusable;
/// the incumbent stays valid when the deadline passes or a probe runs
/// out of budget.
fn minimize_descent(
    b: &mut CnfBuilder,
    params: &ParamVars,
    gates: &BoundedCounter,
    negs: &BoundedCounter,
    base_assum: &[Lit],
    first: SopParams,
    deadline: Option<Instant>,
) -> SopParams {
    let expired =
        |d: &Option<Instant>| d.map(|t| Instant::now() > t).unwrap_or(false);
    let mut best = first;
    // Primary: two-input gate count of the extracted netlist.
    loop {
        let count = gate_count(&best);
        if count == 0 || expired(&deadline) {
            break;
        }
        let mut assum = base_assum.to_vec();
        match gates.at_most(count - 1) {
            None => break,
            Some(l) => assum.push(l),
        }
        match b.solver.solve_limited(&assum) {
            Some(SatResult::Sat) => best = params.extract(b),
            _ => break,
        }
    }
    // Secondary: negations (each costs an inverter), holding the gate
    // bound at the achieved optimum.
    let achieved = gate_count(&best);
    loop {
        let n_negs = best.neg_mask.iter().filter(|&&u| u).count();
        if n_negs == 0 || expired(&deadline) {
            break;
        }
        let mut assum = base_assum.to_vec();
        if let Some(l) = gates.at_most(achieved) {
            assum.push(l);
        }
        match negs.at_most(n_negs - 1) {
            None => break,
            Some(l) => assum.push(l),
        }
        match b.solver.solve_limited(&assum) {
            Some(SatResult::Sat) => best = params.extract(b),
            _ => break,
        }
    }
    best
}

/// Two-input gate count of an instantiation (ANDs beyond the first
/// literal per product + ORs beyond the first selection per sum) —
/// mirrors the miter's gate-proxy counter over concrete params.
pub fn gate_count(p: &SopParams) -> usize {
    let mut c = 0usize;
    for k in 0..p.t {
        let l = (0..p.n).filter(|&j| p.uses(k, j)).count();
        c += l.saturating_sub(1);
    }
    for i in 0..p.m {
        let sels = (0..p.t).filter(|&k| p.selects(i, k)).count();
        c += sels.saturating_sub(1);
    }
    c
}

/// The SHARED-template miter with PIT/ITS restriction counters.
///
/// `Clone` is the prototype mechanism: [`SharedMiter::build`] encodes
/// the base CNF exactly once per geometry, and every clone is a byte-
/// identical snapshot (the solver's clause store is one flat arena, so
/// cloning is a handful of buffer copies, no re-encoding). The canonical
/// parallel scan (`search::engine`) builds one *prototype* per search,
/// blocks the probe model into it, and clones it per lattice cell —
/// each clone then replays exactly the trace a fresh build would, which
/// is why determinism is unaffected (see DESIGN.md §8).
#[derive(Clone)]
pub struct SharedMiter {
    pub b: CnfBuilder,
    pub params: ParamVars,
    pit: BoundedCounter,
    its: BoundedCounter,
    #[allow(dead_code)] // kept: third proxy of the study, and encode-order stability
    lits: BoundedCounter,
    gates: BoundedCounter,
    negs: BoundedCounter,
}

impl SharedMiter {
    /// Encode the miter for `exact` output values (`2^n` entries).
    pub fn build(n: usize, m: usize, t: usize, exact: &[u64], et: u64) -> Self {
        assert_eq!(exact.len(), 1usize << n);
        let mut b = CnfBuilder::new();
        let params = ParamVars::new(&mut b, n, m, t);
        let prods = encode_products(&mut b, &params, exact.len());
        encode_outputs_and_distance(&mut b, &params, &prods, exact, et);

        // u_k <-> OR_i sel_ik : product k is used anywhere.
        let used: Vec<Lit> = (0..t)
            .map(|k| {
                let sels: Vec<Lit> =
                    (0..m).map(|i| params.sel_lits[i * t + k]).collect();
                b.or(&sels)
            })
            .collect();
        let pit = BoundedCounter::new(&mut b, &used);
        let its = BoundedCounter::new(&mut b, &params.sel_lits.clone());
        // Third proxy: total selected literals across the pool. Single-
        // literal products are wires (zero cells), so within a SAT
        // (pit, its) cell, minimising this counter drives the model
        // toward the low-area corner — the "parameters as proxies"
        // thesis applied once more.
        let lits = BoundedCounter::new(&mut b, &params.use_lits.clone());
        let (gates, negs) = encode_gate_proxy(&mut b, &params);
        SharedMiter { b, params, pit, its, lits, gates, negs }
    }

    /// Assumption set enforcing `PIT <= pit && ITS <= its`.
    pub fn restrict(&self, pit: usize, its: usize) -> Vec<Lit> {
        let mut v = Vec::new();
        if let Some(l) = self.pit.at_most(pit) {
            v.push(l);
        }
        if let Some(l) = self.its.at_most(its) {
            v.push(l);
        }
        v
    }

    /// Solve under a (pit, its) restriction.
    pub fn solve(&mut self, pit: usize, its: usize) -> SolveOutcome {
        let assum = self.restrict(pit, its);
        solve_with(&mut self.b, &self.params, &assum)
    }

    /// Solve, then greedily minimise the gate/inverter proxies within the
    /// cell (assumption-only, so the miter stays reusable).
    pub fn solve_minimized(&mut self, pit: usize, its: usize) -> SolveOutcome {
        self.solve_minimized_deadline(pit, its, None)
    }

    /// As [`solve_minimized`](Self::solve_minimized) but stops descending
    /// when the deadline passes (the incumbent stays valid — every probe
    /// is assumption-only).
    pub fn solve_minimized_deadline(
        &mut self,
        pit: usize,
        its: usize,
        deadline: Option<Instant>,
    ) -> SolveOutcome {
        let first = match self.solve(pit, its) {
            SolveOutcome::Sat(p) => p,
            other => return other,
        };
        let base = self.restrict(pit, its);
        SolveOutcome::Sat(minimize_descent(
            &mut self.b,
            &self.params,
            &self.gates,
            &self.negs,
            &base,
            first,
            deadline,
        ))
    }

    /// Exclude a returned assignment so the next solve yields a fresh one.
    pub fn block(&mut self, p: &SopParams) {
        let clause = self.params.blocking_clause(p);
        self.b.add_clause(&clause);
    }

    pub fn set_conflict_budget(&mut self, budget: Option<u64>) {
        self.b.solver.conflict_budget = budget;
    }

    /// Run the solver's once-per-formula preprocessing (failed-literal
    /// probing + binary subsumption). Call on the *prototype* before
    /// cloning: every per-cell clone inherits the simplified CNF, so the
    /// cost is amortised across the lattice. Idempotent and
    /// deterministic — clones of a preprocessed prototype replay exactly.
    pub fn preprocess(&mut self) {
        self.b.solver.preprocess();
    }

    /// Snapshot of the underlying solver's cumulative statistics, for
    /// observe-only per-cell effort deltas (`sat::Stats::delta_since`).
    pub fn stats(&self) -> crate::sat::Stats {
        self.b.solver.stats.clone()
    }
}

/// The nonshared (original XPAT) miter: `t` products *per output*, each
/// output owning a disjoint block, with LPP/PPO restriction counters.
///
/// `Clone` makes it a prototype exactly like [`SharedMiter`]: build once
/// per geometry, clone per lattice cell.
#[derive(Clone)]
pub struct NonsharedMiter {
    pub b: CnfBuilder,
    pub params: ParamVars,
    lpp: Vec<BoundedCounter>, // one per product
    ppo: Vec<BoundedCounter>, // one per output (over its block)
    gates: BoundedCounter,
    negs: BoundedCounter,
}

impl NonsharedMiter {
    /// `k` is the per-output product budget; the underlying pool has
    /// `m*k` products with a block-diagonal, *hard-wired* selection
    /// gated by per-(output, slot) inclusion vars — faithfully eq. (1)
    /// plus the ability to leave a slot unused.
    pub fn build(n: usize, m: usize, k: usize, exact: &[u64], et: u64) -> Self {
        assert_eq!(exact.len(), 1usize << n);
        let t = m * k;
        let mut b = CnfBuilder::new();
        let params = ParamVars::new(&mut b, n, m, t);
        // Hard-wire the block structure: output i may select only its
        // own block of products.
        for i in 0..m {
            for kk in 0..t {
                let owner = kk / k;
                if owner != i {
                    let l = params.sel_lits[i * t + kk];
                    b.add_clause(&[!l]);
                }
            }
        }
        let prods = encode_products(&mut b, &params, exact.len());
        encode_outputs_and_distance(&mut b, &params, &prods, exact, et);

        let lpp = (0..t)
            .map(|kk| {
                let lits: Vec<Lit> =
                    (0..n).map(|j| params.use_lits[kk * n + j]).collect();
                BoundedCounter::new(&mut b, &lits)
            })
            .collect();
        let ppo = (0..m)
            .map(|i| {
                let lits: Vec<Lit> = (0..k)
                    .map(|slot| params.sel_lits[i * t + (i * k + slot)])
                    .collect();
                BoundedCounter::new(&mut b, &lits)
            })
            .collect();
        let (gates, negs) = encode_gate_proxy(&mut b, &params);
        NonsharedMiter { b, params, lpp, ppo, gates, negs }
    }

    /// Assumptions enforcing `LPP <= lpp` on every product and
    /// `PPO <= ppo` on every output.
    pub fn restrict(&self, lpp: usize, ppo: usize) -> Vec<Lit> {
        let mut v = Vec::new();
        for c in &self.lpp {
            if let Some(l) = c.at_most(lpp) {
                v.push(l);
            }
        }
        for c in &self.ppo {
            if let Some(l) = c.at_most(ppo) {
                v.push(l);
            }
        }
        v
    }

    pub fn solve(&mut self, lpp: usize, ppo: usize) -> SolveOutcome {
        let assum = self.restrict(lpp, ppo);
        solve_with(&mut self.b, &self.params, &assum)
    }

    /// Gate/inverter minimisation within an (lpp, ppo) cell — parity with
    /// [`SharedMiter::solve_minimized`].
    pub fn solve_minimized(&mut self, lpp: usize, ppo: usize) -> SolveOutcome {
        self.solve_minimized_deadline(lpp, ppo, None)
    }

    /// Deadline-aware minimisation so the XPAT path honours the search
    /// wall clock *inside* the cell loop, not only between cells.
    pub fn solve_minimized_deadline(
        &mut self,
        lpp: usize,
        ppo: usize,
        deadline: Option<Instant>,
    ) -> SolveOutcome {
        let first = match self.solve(lpp, ppo) {
            SolveOutcome::Sat(p) => p,
            other => return other,
        };
        let base = self.restrict(lpp, ppo);
        SolveOutcome::Sat(minimize_descent(
            &mut self.b,
            &self.params,
            &self.gates,
            &self.negs,
            &base,
            first,
            deadline,
        ))
    }

    pub fn block(&mut self, p: &SopParams) {
        let clause = self.params.blocking_clause(p);
        self.b.add_clause(&clause);
    }

    pub fn set_conflict_budget(&mut self, budget: Option<u64>) {
        self.b.solver.conflict_budget = budget;
    }

    /// Prototype-time preprocessing — see [`SharedMiter::preprocess`].
    pub fn preprocess(&mut self) {
        self.b.solver.preprocess();
    }

    /// Solver-statistics snapshot — see [`SharedMiter::stats`].
    pub fn stats(&self) -> crate::sat::Stats {
        self.b.solver.stats.clone()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::circuit::generators::{adder, multiplier};
    use crate::circuit::sim::{is_sound, TruthTables};

    fn exact_values(nl: &crate::circuit::Netlist) -> Vec<u64> {
        TruthTables::simulate(nl).output_values(nl)
    }

    #[test]
    fn shared_miter_solution_is_sound() {
        let nl = adder(2);
        let exact = exact_values(&nl);
        let mut miter = SharedMiter::build(4, 3, 8, &exact, 1);
        let sol = miter.solve(8, 24).sat().expect("unrestricted must be SAT");
        assert!(is_sound(&exact, &sol.output_values(), 1),
                "max err {:?}", crate::circuit::sim::error_stats(&exact, &sol.output_values()));
    }

    #[test]
    fn shared_miter_et_zero_reproduces_exact_function() {
        let nl = multiplier(2);
        let exact = exact_values(&nl);
        let mut miter = SharedMiter::build(4, 4, 12, &exact, 0);
        let sol = miter.solve(12, 48).sat().expect("ET=0 with a big pool must be SAT");
        assert_eq!(sol.output_values(), exact);
    }

    #[test]
    fn shared_restriction_monotone() {
        // If (pit, its) is SAT then any weaker cell is SAT too.
        let nl = adder(2);
        let exact = exact_values(&nl);
        let mut miter = SharedMiter::build(4, 3, 6, &exact, 2);
        let mut first_sat: Option<(usize, usize)> = None;
        for pit in 1..=6 {
            if miter.solve(pit, 2 * pit).is_sat() {
                first_sat = Some((pit, 2 * pit));
                break;
            }
        }
        let (pit, its) = first_sat.expect("some cell must be SAT");
        assert!(miter.solve(pit + 1, its + 1).is_sat());
    }

    #[test]
    fn shared_restriction_bounds_are_respected() {
        let nl = adder(2);
        let exact = exact_values(&nl);
        let mut miter = SharedMiter::build(4, 3, 8, &exact, 2);
        for (pit, its) in [(2, 4), (3, 6), (4, 8)] {
            if let Some(sol) = miter.solve(pit, its).sat() {
                assert!(sol.pit() <= pit, "pit {} > {}", sol.pit(), pit);
                assert!(sol.its() <= its, "its {} > {}", sol.its(), its);
                assert!(is_sound(&exact, &sol.output_values(), 2));
            }
        }
    }

    #[test]
    fn blocking_enumerates_distinct_solutions() {
        let nl = adder(2);
        let exact = exact_values(&nl);
        let mut miter = SharedMiter::build(4, 3, 6, &exact, 2);
        let s1 = miter.solve(4, 10).sat().expect("sat");
        miter.block(&s1);
        let s2 = miter.solve(4, 10).sat().expect("second solution");
        assert_ne!(s1, s2);
        assert!(is_sound(&exact, &s2.output_values(), 2));
    }

    #[test]
    fn nonshared_miter_solution_is_sound_and_blocked() {
        let nl = adder(2);
        let exact = exact_values(&nl);
        let mut miter = NonsharedMiter::build(4, 3, 3, &exact, 1);
        let sol = miter.solve(4, 3).sat().expect("must be SAT");
        assert!(is_sound(&exact, &sol.output_values(), 1));
        // Block structure: every selected product belongs to its output.
        for i in 0..3 {
            for kk in 0..sol.t {
                if sol.selects(i, kk) {
                    assert_eq!(kk / 3, i, "cross-block selection");
                }
            }
        }
        assert!(sol.lpp() <= 4);
        assert!(sol.ppo() <= 3);
    }

    #[test]
    fn nonshared_lpp_restriction_bites() {
        let nl = multiplier(2);
        let exact = exact_values(&nl);
        let mut miter = NonsharedMiter::build(4, 4, 2, &exact, 0);
        // LPP = 0 means only constant products: mult cannot be exact.
        assert_eq!(miter.solve(0, 2), SolveOutcome::Unsat);
    }

    #[test]
    fn gate_count_matches_netlist_two_input_gates() {
        use crate::template::params::SopParams;
        use crate::util::Rng;
        let mut rng = Rng::seed_from(5);
        for _ in 0..20 {
            let p = SopParams::random(&mut rng, 4, 3, 5, 0.5, 0.4);
            // gate_count counts AND2/OR2 equivalents of the *raw* SOP
            // shape; the netlist uses n-ary gates, so compare against the
            // same arithmetic on the netlist structure.
            let mut want = 0usize;
            for k in 0..p.t {
                if (0..p.m).any(|i| p.selects(i, k)) || true {
                    let l = (0..p.n).filter(|&j| p.uses(k, j)).count();
                    want += l.saturating_sub(1);
                }
            }
            for i in 0..p.m {
                let sels = (0..p.t).filter(|&k| p.selects(i, k)).count();
                want += sels.saturating_sub(1);
            }
            assert_eq!(super::gate_count(&p), want);
        }
    }

    #[test]
    fn minimized_solution_never_worse_than_plain() {
        let nl = adder(2);
        let exact = exact_values(&nl);
        let mut m1 = SharedMiter::build(4, 3, 8, &exact, 2);
        let plain = m1.solve(8, 24).sat().unwrap();
        let mut m2 = SharedMiter::build(4, 3, 8, &exact, 2);
        let minimized = m2.solve_minimized(8, 24).sat().unwrap();
        assert!(super::gate_count(&minimized) <= super::gate_count(&plain));
        assert!(crate::circuit::sim::is_sound(
            &exact, &minimized.output_values(), 2
        ));
    }

    #[test]
    fn nonshared_minimized_solution_never_worse_than_plain() {
        // Parity with the SHARED path: the XPAT miter minimises too.
        let nl = adder(2);
        let exact = exact_values(&nl);
        let mut m1 = NonsharedMiter::build(4, 3, 3, &exact, 2);
        let plain = m1.solve(4, 3).sat().unwrap();
        let mut m2 = NonsharedMiter::build(4, 3, 3, &exact, 2);
        let minimized = m2.solve_minimized(4, 3).sat().unwrap();
        assert!(super::gate_count(&minimized) <= super::gate_count(&plain));
        assert!(is_sound(&exact, &minimized.output_values(), 2));
        // The minimised model still respects the cell bounds.
        assert!(minimized.lpp() <= 4);
        assert!(minimized.ppo() <= 3);
    }

    #[test]
    fn nonshared_minimized_deadline_in_past_still_returns_incumbent() {
        // An already-expired deadline must degrade gracefully to the
        // plain first model, never to a lost answer.
        let nl = adder(2);
        let exact = exact_values(&nl);
        let mut miter = NonsharedMiter::build(4, 3, 3, &exact, 2);
        let past = Instant::now() - std::time::Duration::from_millis(10);
        let sol = miter.solve_minimized_deadline(4, 3, Some(past)).sat();
        assert!(sol.is_some(), "expired deadline must still return the first model");
        assert!(is_sound(&exact, &sol.unwrap().output_values(), 2));
    }

    #[test]
    fn cloned_prototype_replays_fresh_build_exactly() {
        // A clone of a pristine prototype must enumerate byte-identical
        // models to an independently built miter (clone = snapshot; the
        // canonical parallel scan's determinism rests on this).
        let nl = adder(2);
        let exact = exact_values(&nl);
        let mut fresh = SharedMiter::build(4, 3, 6, &exact, 2);
        let proto = SharedMiter::build(4, 3, 6, &exact, 2);
        let mut cloned = proto.clone();
        for round in 0..3 {
            let a = fresh.solve_minimized(4, 10).sat();
            let b = cloned.solve_minimized(4, 10).sat();
            assert_eq!(a, b, "round {round}");
            match (a, b) {
                (Some(pa), Some(pb)) => {
                    fresh.block(&pa);
                    cloned.block(&pb);
                }
                _ => break,
            }
        }
    }

    #[test]
    fn clone_performs_no_cnf_reencoding() {
        let nl = adder(2);
        let exact = exact_values(&nl);
        let proto = SharedMiter::build(4, 3, 6, &exact, 2);
        let encoded = proto.b.clauses_added();
        let mut cloned = proto.clone();
        assert_eq!(cloned.b.clauses_added(), encoded, "clone re-encoded");
        // Solving is assumption-only: still no new clauses.
        let sol = cloned.solve(4, 10).sat().expect("sat");
        assert_eq!(cloned.b.clauses_added(), encoded);
        // Blocking appends exactly one clause — the only growth a
        // canonical-mode per-cell clone ever sees.
        cloned.block(&sol);
        assert_eq!(cloned.b.clauses_added(), encoded + 1);
        // The prototype itself is untouched throughout.
        assert_eq!(proto.b.clauses_added(), encoded);
    }

    #[test]
    fn infeasible_tight_cell_is_unsat_not_wrong() {
        let nl = multiplier(2);
        let exact = exact_values(&nl);
        let mut miter = SharedMiter::build(4, 4, 8, &exact, 0);
        // PIT = 0 forces all outputs constant; mult_i4 with ET=0 cannot
        // be constant, so this must be UNSAT (None), never a bad model.
        assert_eq!(miter.solve(0, 0), SolveOutcome::Unsat);
    }
}

//! The paper's core machinery: parametrisable sum-of-products templates
//! and the error miter.
//!
//! * [`params`] — a concrete template instantiation ([`SopParams`]): the
//!   assignment the SMT search produces, with direct evaluation, netlist
//!   extraction and the PIT/ITS/LPP/PPO proxy metrics of §III.
//! * [`miter`] — the ∀-expanded error miter (Fig. 1) for both the SHARED
//!   template (eq. 2) and the nonshared XPAT template (eq. 1), encoded
//!   into CNF with assumption-based restriction counters so the lattice
//!   search tightens/weakens PIT/ITS (resp. LPP/PPO) without re-encoding.
//!
//! Both miters answer restriction queries with a [`SolveOutcome`], which
//! keeps "the cell is UNSAT" distinct from "the solver gave up on its
//! conflict budget" — the search telemetry depends on that distinction.

pub mod miter;
pub mod params;

pub use miter::{NonsharedMiter, SharedMiter};
pub use params::SopParams;

/// Result of solving one restriction cell.
#[derive(Debug, Clone, PartialEq)]
pub enum SolveOutcome {
    /// A model satisfying the restriction (already extracted).
    Sat(SopParams),
    /// Proven unsatisfiable under the restriction.
    Unsat,
    /// The per-solve conflict budget ran out before an answer — neither
    /// SAT nor UNSAT may be concluded.
    Budget,
}

impl SolveOutcome {
    /// The model, if any — collapses `Unsat`/`Budget` to `None` for
    /// callers that only care about models.
    pub fn sat(self) -> Option<SopParams> {
        match self {
            SolveOutcome::Sat(p) => Some(p),
            _ => None,
        }
    }

    pub fn is_sat(&self) -> bool {
        matches!(self, SolveOutcome::Sat(_))
    }
}

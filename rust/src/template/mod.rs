//! The paper's core machinery: parametrisable sum-of-products templates
//! and the error miter.
//!
//! * [`params`] — a concrete template instantiation ([`SopParams`]): the
//!   assignment the SMT search produces, with direct evaluation, netlist
//!   extraction and the PIT/ITS/LPP/PPO proxy metrics of §III.
//! * [`miter`] — the ∀-expanded error miter (Fig. 1) for both the SHARED
//!   template (eq. 2) and the nonshared XPAT template (eq. 1), encoded
//!   into CNF with assumption-based restriction counters so the lattice
//!   search tightens/weakens PIT/ITS (resp. LPP/PPO) without re-encoding.

pub mod miter;
pub mod params;

pub use miter::{NonsharedMiter, SharedMiter};
pub use params::SopParams;

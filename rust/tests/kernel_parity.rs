//! Differential fuzz for the compiled batch kernels (`nn::kernel`):
//! across random model geometries, random exact/approximate LUTs and
//! adversarial batch shapes, `CompiledMlp` must agree byte-for-byte
//! with per-image `QuantMlp::infer` and the scalar `classify_batch`
//! oracle — plus serving-layer integration: a hot-reload recompiles
//! the kernel atomically without dropping in-flight requests, and a
//! `--scalar-path` server answers with identical bytes. Its own named
//! CI step, like the serve/dist roundtrips.

use std::collections::BTreeMap;
use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::path::{Path, PathBuf};
use std::sync::Arc;
use std::time::Duration;

use sxpat::coordinator::{Method, RunRecord};
use sxpat::nn::digits::N_CLASSES;
use sxpat::nn::{synthetic_digits, CompiledMlp, MultLut, QuantMlp, LANES};
use sxpat::serve::protocol::{
    parse_response, render_control_request, render_infer_request,
};
use sxpat::serve::{parse_tiers, serving_mlp, Registry, ServeConfig, Server};
use sxpat::store::{Fingerprint, Store};
use sxpat::util::Json;
use sxpat::util::Rng;

/// A random valid model: weights over the full magnitude/sign range,
/// geometry drawn per round (not just the 64-input serving shape).
fn random_mlp(rng: &mut Rng) -> QuantMlp {
    let hidden = 1 + rng.usize_below(20);
    let n_in = 1 + rng.usize_below(96);
    let mut w = |n: usize| -> Vec<(u8, bool)> {
        (0..n).map(|_| (rng.below(16) as u8, rng.chance(0.5))).collect()
    };
    let w1 = w(hidden * n_in);
    let w2 = w(N_CLASSES * hidden);
    QuantMlp::from_weights(hidden, w1, w2)
}

/// A random LUT: exact, exact-with-masked-low-bits (sound, the store's
/// family), or per-entry jittered (unsound as an operator, but the
/// kernel must still mirror whatever the LUT says).
fn random_lut(rng: &mut Rng) -> MultLut {
    match rng.below(3) {
        0 => MultLut::exact(),
        1 => {
            let mask = !((1u64 << (1 + rng.below(3))) - 1);
            let vals: Vec<u64> =
                (0..256u64).map(|x| ((x & 15) * (x >> 4)) & mask).collect();
            MultLut::from_values(&vals)
        }
        _ => {
            let vals: Vec<u64> = (0..256u64)
                .map(|x| {
                    let exact = (x & 15) * (x >> 4);
                    if rng.chance(0.25) {
                        (exact + rng.below(40)).min(i16::MAX as u64)
                    } else {
                        exact
                    }
                })
                .collect();
            MultLut::from_values(&vals)
        }
    }
}

fn random_images(rng: &mut Rng, count: usize, n_in: usize) -> Vec<Vec<u8>> {
    (0..count)
        .map(|_| (0..n_in).map(|_| rng.below(16) as u8).collect())
        .collect()
}

#[test]
fn fuzz_compiled_kernel_is_byte_identical_to_scalar_inference() {
    let mut rng = Rng::seed_from(0xC0FFEE);
    for round in 0..25 {
        let mlp = random_mlp(&mut rng);
        let lut = random_lut(&mut rng);
        let kernel = CompiledMlp::try_compile(&mlp, &lut)
            .expect("products are capped at i16::MAX by construction");
        // Empty, single, around the lane width, and a ragged tail.
        for batch in [0, 1, LANES - 1, LANES, LANES + 1, 2 * LANES + 3] {
            let images = random_images(&mut rng, batch, mlp.n_in());
            let refs: Vec<&[u8]> = images.iter().map(Vec::as_slice).collect();
            let per_image: Vec<usize> =
                refs.iter().map(|px| mlp.infer(px, &lut)).collect();
            let scalar = mlp.classify_batch(&refs, &lut);
            let compiled = kernel.classify_batch(&refs);
            assert_eq!(
                compiled, per_image,
                "round {round} batch {batch}: kernel vs per-image infer \
                 (hidden {}, n_in {})",
                mlp.hidden,
                mlp.n_in()
            );
            assert_eq!(compiled, scalar, "round {round} batch {batch}: kernel vs oracle");
        }
    }
}

#[test]
fn fuzz_trained_models_agree_too() {
    // from_weights covers the weight space; train covers the weights a
    // real serving model actually lands on.
    let mut rng = Rng::seed_from(7);
    let data = synthetic_digits(80, 5);
    for hidden in [3, 12] {
        let mlp = QuantMlp::train(&data, hidden, 4, 2);
        for _ in 0..4 {
            let lut = random_lut(&mut rng);
            let kernel = CompiledMlp::compile(&mlp, &lut);
            let images = random_images(&mut rng, 2 * LANES + 5, mlp.n_in());
            let refs: Vec<&[u8]> = images.iter().map(Vec::as_slice).collect();
            assert_eq!(kernel.classify_batch(&refs), mlp.classify_batch(&refs, &lut));
        }
    }
}

#[test]
fn overflowing_lut_fails_compilation_not_inference() {
    let mut vals: Vec<u64> = (0..256u64).map(|x| (x & 15) * (x >> 4)).collect();
    vals[255] = 40_000; // legal on the 16-bit bus, outside i16.
    let lut = MultLut::from_values(&vals);
    let mlp = QuantMlp::from_weights(
        2,
        vec![(15, false); 2 * 3],
        vec![(1, true); N_CLASSES * 2],
    );
    let err = CompiledMlp::try_compile(&mlp, &lut).unwrap_err();
    assert!(err.contains("scalar path"), "{err}");
    // The scalar path still serves that LUT (this is the registry's
    // degradation story: kernel=None, classify_batch oracle).
    let images = random_images(&mut Rng::seed_from(1), 5, 3);
    let refs: Vec<&[u8]> = images.iter().map(Vec::as_slice).collect();
    let labels = mlp.classify_batch(&refs, &lut);
    assert_eq!(labels.len(), 5);
}

// ---------------------------------------------------------------- serving

fn tmp_dir(tag: &str) -> PathBuf {
    let d = std::env::temp_dir()
        .join(format!("sxpat_kernel_it_{tag}_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&d);
    d
}

/// A sound mult_i8 record: exact products with the low `mask_bits`
/// output bits cleared, max_err recorded honestly.
fn masked_mult_record(mask_bits: u32, area: f64) -> RunRecord {
    let mask = !((1u64 << mask_bits) - 1);
    let values: Vec<u64> = (0..256u64).map(|x| ((x & 15) * (x >> 4)) & mask).collect();
    let max_err = (0..256u64)
        .map(|x| ((x & 15) * (x >> 4)).abs_diff(((x & 15) * (x >> 4)) & mask))
        .max()
        .unwrap();
    RunRecord {
        bench: "mult_i8",
        method: Method::Shared,
        et: max_err,
        area,
        max_err,
        mean_err: 0.25,
        proxy: (0, 0),
        elapsed_ms: 1,
        cached: false,
        values,
        all_points: Vec::new(),
        error: None,
    }
}

fn start_server(dir: Option<&Path>, tiers: &str, compile_kernels: bool) -> Server {
    let registry = Registry::open(
        "mult_i8",
        parse_tiers(tiers).unwrap(),
        dir,
        Arc::new(serving_mlp()),
        compile_kernels,
    )
    .unwrap();
    Server::start(
        &ServeConfig {
            addr: "127.0.0.1:0".to_string(),
            workers: 2,
            batch: 4,
            batch_wait_ms: 2,
            queue_cap: 1024,
            ..Default::default()
        },
        registry,
    )
    .unwrap()
}

struct Client {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
}

impl Client {
    fn connect(addr: std::net::SocketAddr) -> Client {
        let stream = TcpStream::connect(addr).unwrap();
        let _ = stream.set_nodelay(true);
        stream.set_read_timeout(Some(Duration::from_secs(60))).unwrap();
        Client { reader: BufReader::new(stream.try_clone().unwrap()), writer: stream }
    }

    fn send(&mut self, line: &str) {
        self.writer.write_all(line.as_bytes()).unwrap();
        self.writer.write_all(b"\n").unwrap();
    }

    fn recv_line(&mut self) -> String {
        let mut line = String::new();
        let n = self.reader.read_line(&mut line).unwrap();
        assert!(n > 0, "server closed the connection");
        line.trim().to_string()
    }

    fn roundtrip(&mut self, line: &str) -> sxpat::serve::protocol::ParsedResponse {
        self.send(line);
        parse_response(&self.recv_line()).unwrap()
    }
}

#[test]
fn hot_reload_recompiles_the_kernel_without_dropping_in_flight_requests() {
    let dir = tmp_dir("reload");
    {
        let store = Store::open(&dir).unwrap();
        store.append(Fingerprint(1), &masked_mult_record(3, 40.0)).unwrap();
    }
    let server = start_server(Some(dir.as_path()), "silver=8", true);
    let images = synthetic_digits(10, 55);
    let mut c = Client::connect(server.addr());

    // Baseline: the tier serves the stored operator on the compiled path.
    let stats = c.roundtrip(&render_control_request("stats", 500));
    let snap = stats.raw.get("stats").expect("stats payload");
    assert_eq!(
        snap.get("tier.silver.path").and_then(Json::as_str),
        Some("compiled"),
        "{snap:?}"
    );
    let before = c.roundtrip(&render_infer_request(1000, "silver", &images[0].pixels));
    assert!(before.ok);
    let before_src = before.raw.get("source").and_then(Json::as_str).unwrap().to_string();

    // A better operator lands in the WAL.
    {
        let store = Store::open(&dir).unwrap();
        store.append(Fingerprint(2), &masked_mult_record(2, 9.5)).unwrap();
    }

    // Pipeline across the reload: 5 infers, reload, 5 infers — every
    // request answered, none dropped while the kernel is recompiled
    // and the tier map swapped.
    for (i, s) in images[..5].iter().enumerate() {
        c.send(&render_infer_request(i as u64, "silver", &s.pixels));
    }
    c.send(&render_control_request("reload", 77));
    for (i, s) in images[5..].iter().enumerate() {
        c.send(&render_infer_request(5 + i as u64, "silver", &s.pixels));
    }
    let mut answered = BTreeMap::new();
    for _ in 0..11 {
        let resp = parse_response(&c.recv_line()).unwrap();
        assert!(resp.ok, "{:?}", resp.error);
        answered.insert(resp.id, resp);
    }
    assert_eq!(answered.len(), 11, "10 infers + 1 reload, nothing dropped");
    assert!(answered.contains_key(&77));

    // Post-reload: new operator, still on the compiled path, and the
    // served labels match direct inference through the new LUT.
    let after = c.roundtrip(&render_infer_request(2000, "silver", &images[0].pixels));
    assert!(after.ok);
    let after_src = after.raw.get("source").and_then(Json::as_str).unwrap();
    assert_ne!(after_src, before_src, "reload must swap the operator");
    let stats = c.roundtrip(&render_control_request("stats", 501));
    let snap = stats.raw.get("stats").expect("stats payload");
    assert_eq!(snap.get("tier.silver.path").and_then(Json::as_str), Some("compiled"));

    let mask = !((1u64 << 2) - 1);
    let vals: Vec<u64> = (0..256u64).map(|x| ((x & 15) * (x >> 4)) & mask).collect();
    let want = serving_mlp().infer(&images[0].pixels, &MultLut::from_values(&vals));
    assert_eq!(after.label, Some(want as u64));

    server.shutdown();
    server.join();
    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn scalar_path_server_answers_byte_identically() {
    let dir = tmp_dir("scalar");
    {
        let store = Store::open(&dir).unwrap();
        store.append(Fingerprint(1), &masked_mult_record(3, 40.0)).unwrap();
    }
    let tiers = "gold=0,silver=8";
    let images = synthetic_digits(20, 77);

    let mut lines_by_mode = Vec::new();
    for compile_kernels in [true, false] {
        let server = start_server(Some(dir.as_path()), tiers, compile_kernels);
        let mut c = Client::connect(server.addr());

        let stats = c.roundtrip(&render_control_request("stats", 900));
        let snap = stats.raw.get("stats").expect("stats payload");
        let want_path = if compile_kernels { "compiled" } else { "scalar" };
        for tier in ["gold", "silver"] {
            assert_eq!(
                snap.get(&format!("tier.{tier}.path")).and_then(Json::as_str),
                Some(want_path)
            );
        }

        let mut lines = BTreeMap::new();
        for (i, s) in images.iter().enumerate() {
            let tier = if i % 2 == 0 { "gold" } else { "silver" };
            c.send(&render_infer_request(i as u64, tier, &s.pixels));
        }
        for _ in 0..images.len() {
            let line = c.recv_line();
            let resp = parse_response(&line).unwrap();
            assert!(resp.ok, "{:?}", resp.error);
            lines.insert(resp.id, line);
        }
        lines_by_mode.push(lines);
        server.shutdown();
        server.join();
    }
    assert_eq!(
        lines_by_mode[0], lines_by_mode[1],
        "compiled and --scalar-path servers must answer byte-identically"
    );
    std::fs::remove_dir_all(&dir).unwrap();
}

//! Distributed-sweep integration: real multi-worker sweeps over
//! loopback TCP, in-process. The acceptance bar: a 4-worker
//! distributed sweep yields a record set, fig5 CSV and WAL
//! byte-identical (modulo the `cached`/`elapsed_ms` provenance
//! columns) to the sequential `run_sweep_stored` baseline — including
//! across the worker-kill and lease-expiry requeue paths — with
//! exactly one WAL line per job (fingerprint dedup, first-committed
//! wins). Part of the tier-1 test path (plain `cargo test`).

use std::collections::BTreeMap;
use std::net::{SocketAddr, TcpStream};
use std::path::{Path, PathBuf};
use std::time::Duration;

use sxpat::circuit::generators::benchmark_by_name;
use sxpat::coordinator::{run_job, run_sweep_stored, Job, Method, RunRecord, SweepPlan};
use sxpat::dist::protocol::{CoordMsg, WorkerMsg, PROTO_VERSION};
use sxpat::dist::{Coordinator, DistConfig, WorkerConfig};
use sxpat::report::fig5_csv;
use sxpat::search::SearchConfig;
use sxpat::store::Store;
use sxpat::util::jsonl::{self, LineRead};
use sxpat::util::Json;

fn tmp_dir(tag: &str) -> PathBuf {
    let d = std::env::temp_dir()
        .join(format!("sxpat_dist_it_{tag}_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&d);
    d
}

fn tiny_plan() -> SweepPlan {
    SweepPlan {
        benches: vec![benchmark_by_name("adder_i4").unwrap()],
        methods: vec![Method::Shared, Method::Muscat],
        ets: Some(vec![1, 2]),
        search: SearchConfig {
            pool: 5,
            solutions_per_cell: 1,
            max_sat_cells: 1,
            conflict_budget: Some(20_000),
            time_budget_ms: 20_000,
            ..Default::default()
        },
        workers: 1,
    }
}

fn dist_cfg() -> DistConfig {
    DistConfig {
        addr: "127.0.0.1:0".to_string(),
        lease_ms: 60_000,
        wait_ms: 25,
        ..Default::default()
    }
}

/// Everything that must agree between a local and a distributed run of
/// the same job (all fields except the provenance pair
/// `elapsed_ms`/`cached`).
fn result_key(r: &RunRecord) -> impl PartialEq + std::fmt::Debug {
    (
        r.bench,
        r.method,
        r.et,
        r.area.to_bits(),
        r.max_err,
        r.mean_err.to_bits(),
        r.proxy,
        r.values.clone(),
        r.all_points.len(),
        r.error.clone(),
    )
}

/// Drop the trailing `cached` column from every fig5 CSV row.
fn strip_cached_column(csv: &str) -> String {
    csv.lines()
        .map(|l| match l.rsplit_once(',') {
            Some((head, _)) => head.to_string(),
            None => l.to_string(),
        })
        .collect::<Vec<_>>()
        .join("\n")
}

/// The WAL with every record's `elapsed_ms` zeroed — the only field a
/// distributed run may legitimately differ in (it reports the remote
/// worker's clock).
fn normalized_wal(dir: &Path) -> Vec<String> {
    let text = std::fs::read_to_string(dir.join("wal.jsonl")).unwrap();
    text.lines()
        .map(|l| {
            let j = Json::parse(l).unwrap();
            let fp = j.get("fp").and_then(Json::as_str).unwrap().to_string();
            let mut rec = RunRecord::from_json(j.get("record").unwrap()).unwrap();
            rec.elapsed_ms = 0;
            let mut m = BTreeMap::new();
            m.insert("fp".to_string(), Json::Str(fp));
            m.insert("record".to_string(), rec.to_json());
            Json::Obj(m).render()
        })
        .collect()
}

fn wal_fingerprints(dir: &Path) -> Vec<String> {
    std::fs::read_to_string(dir.join("wal.jsonl"))
        .unwrap()
        .lines()
        .map(|l| {
            Json::parse(l).unwrap().get("fp").and_then(Json::as_str).unwrap().to_string()
        })
        .collect()
}

/// A protocol-level client for playing misbehaving workers.
struct RawClient {
    reader: std::io::BufReader<TcpStream>,
    writer: TcpStream,
}

impl RawClient {
    fn connect(addr: SocketAddr) -> RawClient {
        let stream = TcpStream::connect(addr).unwrap();
        let reader = std::io::BufReader::new(stream.try_clone().unwrap());
        RawClient { reader, writer: stream }
    }

    /// Send one message; `None` when the coordinator has hung up.
    fn exchange(&mut self, msg: &WorkerMsg) -> Option<CoordMsg> {
        if jsonl::send_line(&mut self.writer, &msg.render()).is_err() {
            return None;
        }
        match jsonl::read_line(&mut self.reader) {
            LineRead::Line(l) => Some(CoordMsg::parse(&l).unwrap()),
            _ => None,
        }
    }

    fn hello(&mut self) {
        let msg = WorkerMsg::Hello { name: "griefer".to_string(), proto: PROTO_VERSION };
        match self.exchange(&msg) {
            Some(CoordMsg::Welcome { .. }) => {}
            other => panic!("expected welcome, got {other:?}"),
        }
    }

    fn take_lease(&mut self) -> (usize, Job) {
        match self.exchange(&WorkerMsg::LeaseRequest { telemetry: None }) {
            Some(CoordMsg::Lease { job, bench, method, et, search, .. }) => (
                job,
                Job { bench: benchmark_by_name(&bench).unwrap(), method, et, search },
            ),
            other => panic!("expected a lease, got {other:?}"),
        }
    }
}

fn spawn_workers<'s, 'e>(
    s: &'s std::thread::Scope<'s, 'e>,
    addr: SocketAddr,
    n: usize,
) -> Vec<std::thread::ScopedJoinHandle<'s, sxpat::dist::WorkerStats>> {
    (0..n)
        .map(|i| {
            s.spawn(move || {
                sxpat::dist::run_worker(&WorkerConfig {
                    addr: addr.to_string(),
                    name: format!("w{i}"),
                    cell_workers: None,
                    max_jobs: None,
                    ..Default::default()
                })
                .unwrap()
            })
        })
        .collect()
}

#[test]
fn four_worker_sweep_matches_sequential_baseline() {
    let plan = tiny_plan();

    // Sequential baseline: one worker, so WAL lines land in job order —
    // the order the distributed commit frontier must reproduce.
    let base_dir = tmp_dir("base");
    let base = {
        let store = Store::open(&base_dir).unwrap();
        run_sweep_stored(&plan, Some(&store))
    };
    assert!(base.iter().all(|r| r.error.is_none() && !r.cached));

    let dist_dir = tmp_dir("dist4");
    let store = Store::open(&dist_dir).unwrap();
    let (records, stats) = std::thread::scope(|s| {
        let coord = Coordinator::bind(&plan, Some(&store), &dist_cfg()).unwrap();
        let addr = coord.addr();
        let run = s.spawn(move || coord.run().unwrap());
        let workers = spawn_workers(s, addr, 4);
        let records = run.join().unwrap();
        let stats: Vec<_> = workers.into_iter().map(|w| w.join().unwrap()).collect();
        (records, stats)
    });

    // Every job ran remotely, exactly once across the fleet.
    assert_eq!(records.len(), plan.n_jobs());
    assert!(records.iter().all(|r| !r.cached && r.error.is_none()));
    let completed: usize = stats.iter().map(|st| st.completed).sum();
    assert_eq!(completed, plan.n_jobs(), "each job solved exactly once");

    // Record-set equality, modulo provenance.
    for (a, b) in base.iter().zip(&records) {
        assert_eq!(result_key(a), result_key(b));
    }

    // fig5 CSV byte-identical modulo the cached column.
    assert_eq!(
        strip_cached_column(&fig5_csv(&base)),
        strip_cached_column(&fig5_csv(&records))
    );

    // WAL byte-identical modulo elapsed_ms — including line ORDER
    // (in-order commit by job index, regardless of completion order).
    assert_eq!(normalized_wal(&base_dir), normalized_wal(&dist_dir));

    drop(store);
    std::fs::remove_dir_all(&base_dir).unwrap();
    std::fs::remove_dir_all(&dist_dir).unwrap();
}

#[test]
fn storeless_distributed_sweep_matches_local_run() {
    let plan = tiny_plan();
    let base = run_sweep_stored(&plan, None);
    let records = std::thread::scope(|s| {
        let coord = Coordinator::bind(&plan, None, &dist_cfg()).unwrap();
        let addr = coord.addr();
        let run = s.spawn(move || coord.run().unwrap());
        let _ = spawn_workers(s, addr, 2);
        run.join().unwrap()
    });
    for (a, b) in base.iter().zip(&records) {
        assert_eq!(result_key(a), result_key(b));
    }
}

#[test]
fn killed_and_wedged_workers_requeue_with_one_wal_line_per_job() {
    // Two jobs, two griefers, then a real fleet:
    //  - griefer A takes a lease and disconnects (death → immediate requeue);
    //  - griefer B takes a lease and goes silent past the lease deadline
    //    (expiry → reaper requeue), then submits late anyway.
    // Invariants: the sweep completes, the records match the sequential
    // baseline, and the WAL holds exactly one line per job.
    let plan = SweepPlan { methods: vec![Method::Shared], ..tiny_plan() };
    assert_eq!(plan.n_jobs(), 2);

    let base_dir = tmp_dir("kbase");
    let base = {
        let store = Store::open(&base_dir).unwrap();
        run_sweep_stored(&plan, Some(&store))
    };

    let dist_dir = tmp_dir("kill");
    let store = Store::open(&dist_dir).unwrap();
    let cfg = DistConfig { lease_ms: 300, ..dist_cfg() };
    let records = std::thread::scope(|s| {
        let coord = Coordinator::bind(&plan, Some(&store), &cfg).unwrap();
        let addr = coord.addr();
        let run = s.spawn(move || coord.run().unwrap());

        // Griefer A: lease, die.
        let mut a = RawClient::connect(addr);
        a.hello();
        let (idx_a, _) = a.take_lease();
        drop(a);

        // Griefer B: lease, wedge past the deadline.
        let mut b = RawClient::connect(addr);
        b.hello();
        let (idx_b, job_b) = b.take_lease();
        assert_ne!(idx_a, idx_b, "two jobs, two distinct leases");
        std::thread::sleep(Duration::from_millis(600));

        // B's job has been requeued by now, but B finishes anyway and
        // submits first: first-committed wins, the work is accepted.
        let record = run_job(&job_b);
        match b.exchange(&WorkerMsg::Result {
            job: idx_b,
            record: record.clone(),
            trace_ctx: None,
        }) {
            Some(CoordMsg::Committed { job, fresh }) => {
                assert_eq!(job, idx_b);
                assert!(fresh, "first sound submission must win");
            }
            other => panic!("expected committed, got {other:?}"),
        }
        // A second submission of the same job is a stale duplicate.
        match b.exchange(&WorkerMsg::Result { job: idx_b, record, trace_ctx: None }) {
            Some(CoordMsg::Committed { fresh, .. }) => {
                assert!(!fresh, "duplicate must be discarded")
            }
            other => panic!("expected stale committed, got {other:?}"),
        }

        // The real fleet completes A's requeued job (and would pick up
        // B's had B never delivered).
        let workers = spawn_workers(s, addr, 2);
        let records = run.join().unwrap();
        for w in workers {
            let _ = w.join().unwrap();
        }
        drop(b);
        records
    });

    assert_eq!(records.len(), 2);
    assert!(records.iter().all(|r| r.error.is_none() && !r.cached));
    for (x, y) in base.iter().zip(&records) {
        assert_eq!(result_key(x), result_key(y));
    }

    // Exactly one WAL line per job — the requeue/duplicate dance must
    // not grow the log — and the lines equal the baseline's.
    let fps = wal_fingerprints(&dist_dir);
    assert_eq!(fps.len(), 2);
    assert_ne!(fps[0], fps[1]);
    assert_eq!(normalized_wal(&base_dir), normalized_wal(&dist_dir));

    drop(store);
    std::fs::remove_dir_all(&base_dir).unwrap();
    std::fs::remove_dir_all(&dist_dir).unwrap();
}

#[test]
fn warm_store_hits_are_served_locally_and_never_leased() {
    // Warm the store with the et=1 half of the grid, then run the full
    // et∈{1,2} grid distributed: the cached half must resolve on the
    // coordinator (cached=true, elapsed 0, no wire traffic), only the
    // cold half crosses to the worker, and the WAL grows by exactly
    // the cold half.
    let mut warm = tiny_plan();
    warm.ets = Some(vec![1]);
    let dir = tmp_dir("warm");
    {
        let store = Store::open(&dir).unwrap();
        run_sweep_stored(&warm, Some(&store));
    }
    let plan = tiny_plan();
    let store = Store::open(&dir).unwrap();
    let lines_before = store.lines();
    assert_eq!(lines_before, warm.n_jobs());
    let (records, stats) = std::thread::scope(|s| {
        let coord = Coordinator::bind(&plan, Some(&store), &dist_cfg()).unwrap();
        let addr = coord.addr();
        let run = s.spawn(move || coord.run().unwrap());
        let workers = spawn_workers(s, addr, 1);
        let records = run.join().unwrap();
        let stats: Vec<_> = workers.into_iter().map(|w| w.join().unwrap()).collect();
        (records, stats)
    });
    assert_eq!(records.len(), plan.n_jobs());
    for r in &records {
        if r.et == 1 {
            assert!(r.cached && r.elapsed_ms == 0, "warm half serves from disk");
        } else {
            assert!(!r.cached, "cold half solved remotely");
        }
    }
    let cold = records.iter().filter(|r| !r.cached).count();
    assert_eq!(stats[0].completed, cold, "only cold jobs crossed the wire");
    assert_eq!(store.lines(), lines_before + cold, "WAL grew by the cold half only");
    drop(store);
    std::fs::remove_dir_all(&dir).unwrap();
}

//! Property-based invariants over randomised inputs (hand-rolled driver;
//! proptest is not vendored in this offline environment — see Cargo.toml
//! note). Each property runs on many seeded random cases so failures
//! reproduce deterministically from the printed seed.

use sxpat::aig::{netlist_to_aig, optimize};
use sxpat::circuit::netlist::{GateKind, Netlist};
use sxpat::circuit::sim::{error_stats, TruthTables};
use sxpat::evaluator::rust_eval::evaluate;
use sxpat::sat::{Lit, SatResult, Solver};
use sxpat::smt::cardinality::at_most_k;
use sxpat::smt::cnf::CnfBuilder;
use sxpat::synth::synthesize_area;
use sxpat::template::SopParams;
use sxpat::util::Rng;

/// Random well-formed netlist with n inputs and a few random gates.
fn random_netlist(rng: &mut Rng, n: usize, n_gates: usize, m: usize) -> Netlist {
    let mut nl = Netlist::new("rand");
    for _ in 0..n {
        nl.add_input();
    }
    for _ in 0..n_gates {
        let avail = nl.gates.len();
        let kind = match rng.below(6) {
            0 => GateKind::And,
            1 => GateKind::Or,
            2 => GateKind::Xor,
            3 => GateKind::Nand,
            4 => GateKind::Nor,
            _ => GateKind::Not,
        };
        let arity = if kind == GateKind::Not { 1 } else { 2 + rng.usize_below(2) };
        let fanins: Vec<u32> =
            (0..arity).map(|_| rng.usize_below(avail) as u32).collect();
        nl.push(kind, fanins);
    }
    let total = nl.gates.len();
    let outs: Vec<u32> = (0..m).map(|_| rng.usize_below(total) as u32).collect();
    nl.set_outputs(outs);
    nl
}

#[test]
fn prop_aig_optimization_preserves_function() {
    for seed in 0..60u64 {
        let mut rng = Rng::seed_from(seed);
        let n = 2 + rng.usize_below(5);
        let g = 4 + rng.usize_below(20);
        let m = 1 + rng.usize_below(4);
        let nl = random_netlist(&mut rng, n, g, m);
        assert!(nl.validate().is_ok(), "seed {seed}");
        let tt = TruthTables::simulate(&nl).output_values(&nl);
        let aig = netlist_to_aig(&nl);
        assert_eq!(aig.output_values(), tt, "netlist->aig seed {seed}");
        let opt = optimize(&aig);
        assert_eq!(opt.output_values(), tt, "optimize seed {seed}");
        assert!(opt.live_and_count() <= aig.live_and_count(), "seed {seed}");
    }
}

#[test]
fn prop_synthesized_area_nonnegative_and_optimization_helps() {
    for seed in 0..25u64 {
        let mut rng = Rng::seed_from(1000 + seed);
        let ni = 3 + rng.usize_below(3);
        let nl = random_netlist(&mut rng, ni, 10, 2);
        let area = synthesize_area(&nl);
        assert!(area >= 0.0 && area.is_finite(), "seed {seed}: {area}");
    }
}

#[test]
fn prop_evaluator_matches_netlist_extraction() {
    // The three evaluation paths (direct semantics, bit-parallel
    // evaluator, netlist extraction + simulation) agree on random params.
    for seed in 0..40u64 {
        let mut rng = Rng::seed_from(2000 + seed);
        let n = 2 + rng.usize_below(5);
        let m = 1 + rng.usize_below(4);
        let t = 1 + rng.usize_below(8);
        let (ld, sd) = (rng.f64(), rng.f64());
        let p = SopParams::random(&mut rng, n, m, t, ld, sd);
        let exact: Vec<u64> =
            (0..1u64 << n).map(|x| x % (1 << m)).collect();
        let r = evaluate(&p, &exact);
        assert_eq!(r.values, p.output_values(), "seed {seed}");
        let nl = p.to_netlist("p");
        let tt = TruthTables::simulate(&nl).output_values(&nl);
        assert_eq!(tt, r.values, "seed {seed}");
        let (mx, mean) = error_stats(&exact, &r.values);
        assert_eq!((mx, mean), (r.max_err, r.mean_err), "seed {seed}");
    }
}

#[test]
fn prop_sat_solver_agrees_with_brute_force() {
    // Random small CNFs, solver vs exhaustive enumeration.
    for seed in 0..80u64 {
        let mut rng = Rng::seed_from(3000 + seed);
        let n = 3 + rng.usize_below(8); // up to 10 vars
        let n_clauses = 2 + rng.usize_below(4 * n);
        let clauses: Vec<Vec<Lit>> = (0..n_clauses)
            .map(|_| {
                let len = 1 + rng.usize_below(3);
                (0..len)
                    .map(|_| Lit::new(rng.usize_below(n) as u32, rng.chance(0.5)))
                    .collect()
            })
            .collect();
        let mut brute = false;
        'outer: for m in 0..1u32 << n {
            for cl in &clauses {
                if !cl
                    .iter()
                    .any(|l| ((m >> l.var()) & 1 == 1) != l.is_neg())
                {
                    continue 'outer;
                }
            }
            brute = true;
            break;
        }
        let mut s = Solver::new();
        for _ in 0..n {
            s.new_var();
        }
        let mut ok = true;
        for cl in &clauses {
            ok &= s.add_clause(cl);
        }
        let got = if ok { s.solve(&[]) == SatResult::Sat } else { false };
        assert_eq!(got, brute, "seed {seed} clauses {clauses:?}");
    }
}

#[test]
fn prop_cardinality_bound_respected_in_models() {
    for seed in 0..30u64 {
        let mut rng = Rng::seed_from(4000 + seed);
        let n = 3 + rng.usize_below(8);
        let k = rng.usize_below(n + 1);
        let mut b = CnfBuilder::new();
        let xs: Vec<Lit> = (0..n).map(|_| b.new_lit()).collect();
        at_most_k(&mut b, &xs, k);
        // Random extra constraints to push the model around.
        for _ in 0..rng.usize_below(4) {
            let x = xs[rng.usize_below(n)];
            b.add_clause(&[if rng.chance(0.5) { x } else { !x }]);
        }
        if b.solver.solve(&[]) == SatResult::Sat {
            let count = xs.iter().filter(|&&x| b.solver.model_value(x)).count();
            assert!(count <= k, "seed {seed}: {count} > {k}");
        }
    }
}

#[test]
fn prop_coordinator_records_are_internally_consistent() {
    use sxpat::circuit::generators::benchmark_by_name;
    use sxpat::coordinator::{run_job, Job, Method};
    use sxpat::search::SearchConfig;
    for seed in 0..6u64 {
        let mut rng = Rng::seed_from(5000 + seed);
        let bench = benchmark_by_name(["adder_i4", "mult_i4"][rng.usize_below(2)]).unwrap();
        let et = 1 + rng.below(2);
        let method = Method::all_compared()[rng.usize_below(4)];
        let rec = run_job(&Job {
            bench,
            method,
            et,
            search: SearchConfig {
                pool: 5,
                solutions_per_cell: 1,
                max_sat_cells: 1,
                conflict_budget: Some(30_000),
                time_budget_ms: 20_000,
                ..Default::default()
            },
        });
        assert_eq!(rec.bench, bench.name);
        assert_eq!(rec.et, et);
        if rec.area.is_finite() {
            assert!(rec.max_err <= et, "seed {seed} {method:?}");
            assert!(rec.mean_err <= rec.max_err as f64 + 1e-9);
        }
    }
}

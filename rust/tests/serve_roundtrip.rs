//! Serving-layer integration: the QoS protocol end to end over real
//! TCP — batched responses byte-identical to direct sequential
//! inference, worker-count/batch-size invariance, structured errors
//! for malformed traffic, and registry hot-reload without dropping
//! in-flight requests. Part of the tier-1 test path (plain
//! `cargo test`) and its own named CI step.

use std::collections::BTreeMap;
use std::io::{BufRead, BufReader, Write};
use std::net::{SocketAddr, TcpStream};
use std::path::{Path, PathBuf};
use std::time::Duration;

use sxpat::circuit::generators::benchmark_by_name;
use sxpat::coordinator::{run_sweep_stored, Method, RunRecord, SweepPlan};
use sxpat::nn::synthetic_digits;
use sxpat::search::SearchConfig;
use sxpat::serve::protocol::{
    parse_response, render_control_request, render_infer_request, ParsedResponse,
};
use sxpat::serve::{parse_tiers, serving_mlp, Registry, ServeConfig, Server};
use sxpat::store::{Fingerprint, Store};
use sxpat::util::Json;

fn tmp_dir(tag: &str) -> PathBuf {
    let d = std::env::temp_dir()
        .join(format!("sxpat_serve_it_{tag}_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&d);
    d
}

/// Populate a store with sound mult_i8 operators (MUSCAT is the fast
/// sound method at i8 scale).
fn build_store(dir: &Path, ets: &[u64]) {
    let plan = SweepPlan {
        benches: vec![benchmark_by_name("mult_i8").unwrap()],
        methods: vec![Method::Muscat],
        ets: Some(ets.to_vec()),
        search: SearchConfig::default(),
        workers: 2,
    };
    let store = Store::open(dir).unwrap();
    let recs = run_sweep_stored(&plan, Some(&store));
    assert!(recs.iter().all(|r| r.error.is_none()));
}

fn start_server(dir: Option<&Path>, tiers: &str, workers: usize, batch: usize) -> Server {
    // Kernels on: these tests exercise the compiled serving path; its
    // byte-identity to direct scalar inference is what they assert.
    let registry = Registry::open(
        "mult_i8",
        parse_tiers(tiers).unwrap(),
        dir,
        std::sync::Arc::new(serving_mlp()),
        true,
    )
    .unwrap();
    Server::start(
        &ServeConfig {
            addr: "127.0.0.1:0".to_string(),
            workers,
            batch,
            batch_wait_ms: 2,
            queue_cap: 1024,
            ..Default::default()
        },
        registry,
    )
    .unwrap()
}

struct Client {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
}

impl Client {
    fn connect(addr: SocketAddr) -> Client {
        let stream = TcpStream::connect(addr).unwrap();
        let _ = stream.set_nodelay(true);
        // A hung server fails the test instead of hanging CI.
        stream.set_read_timeout(Some(Duration::from_secs(60))).unwrap();
        Client { reader: BufReader::new(stream.try_clone().unwrap()), writer: stream }
    }

    fn send(&mut self, line: &str) {
        self.writer.write_all(line.as_bytes()).unwrap();
        self.writer.write_all(b"\n").unwrap();
    }

    /// Read one raw response line (trimmed).
    fn recv_line(&mut self) -> String {
        let mut line = String::new();
        let n = self.reader.read_line(&mut line).unwrap();
        assert!(n > 0, "server closed the connection");
        line.trim().to_string()
    }

    fn recv(&mut self) -> ParsedResponse {
        let line = self.recv_line();
        parse_response(&line).unwrap()
    }

    fn roundtrip(&mut self, line: &str) -> ParsedResponse {
        self.send(line);
        self.recv()
    }
}

/// A sound mult_i8 record with the low `mask_bits` output bits cleared
/// and an artificially tiny area — "a strictly better operator".
fn masked_mult_record(mask_bits: u32, area: f64) -> RunRecord {
    let mask = !((1u64 << mask_bits) - 1);
    let values: Vec<u64> = (0..256u64).map(|x| ((x & 15) * (x >> 4)) & mask).collect();
    let max_err = (0..256u64)
        .map(|x| ((x & 15) * (x >> 4)).abs_diff(((x & 15) * (x >> 4)) & mask))
        .max()
        .unwrap();
    RunRecord {
        bench: "mult_i8",
        method: Method::Shared,
        et: max_err,
        area,
        max_err,
        mean_err: 0.25,
        proxy: (0, 0),
        elapsed_ms: 1,
        cached: false,
        values,
        all_points: Vec::new(),
        error: None,
    }
}

#[test]
fn mixed_tier_responses_match_direct_inference() {
    let dir = tmp_dir("mixed");
    build_store(&dir, &[4, 8]);
    let tiers = "gold=0,silver=4,bronze=16";
    let server = start_server(Some(dir.as_path()), tiers, 2, 4);

    // An identical, independent resolution for the direct path — on
    // the scalar oracle, so server responses (compiled kernels) are
    // checked against independent scalar inference.
    let mlp = serving_mlp();
    let reference = Registry::open(
        "mult_i8",
        parse_tiers(tiers).unwrap(),
        Some(dir.as_path()),
        std::sync::Arc::new(mlp.clone()),
        false,
    )
    .unwrap();

    let names = ["gold", "silver", "bronze"];
    let images = synthetic_digits(30, 123);
    let mut c = Client::connect(server.addr());
    for (i, s) in images.iter().enumerate() {
        let tier = names[i % names.len()];
        let resp = c.roundtrip(&render_infer_request(i as u64, tier, &s.pixels));
        assert!(resp.ok, "{:?}", resp.error);
        assert_eq!(resp.id, i as u64);
        let resolved = reference.resolve(tier).unwrap();
        let want = mlp.infer(&s.pixels, &resolved.lut);
        assert_eq!(resp.label, Some(want as u64), "request {i} tier {tier}");
        // Provenance mirrors the registry resolution exactly.
        assert_eq!(resp.raw.get("area"), Some(&Json::Num(resolved.area)));
        assert_eq!(
            resp.raw.get("source").and_then(Json::as_str),
            Some(resolved.source_str().as_str())
        );
    }

    // The silver/bronze tiers really serve library operators (the
    // store has sound MUSCAT results within those budgets).
    for tier in ["silver", "bronze"] {
        let src = reference.resolve(tier).unwrap().source_str();
        assert!(src.starts_with("oplib:MUSCAT:"), "{tier}: {src}");
    }

    // Per-tier metrics are queryable over the wire.
    let stats = c.roundtrip(&render_control_request("stats", 999));
    assert!(stats.ok);
    let snap = stats.raw.get("stats").expect("stats payload");
    assert_eq!(snap.get("tier.gold.requests").and_then(Json::as_u64), Some(10));
    assert_eq!(snap.get("tier.silver.requests").and_then(Json::as_u64), Some(10));
    assert_eq!(snap.get("bench").and_then(Json::as_str), Some("mult_i8"));

    server.shutdown();
    server.join();
    std::fs::remove_dir_all(&dir).unwrap();
}

/// Pipeline a fixed mixed-tier workload and collect id -> raw response
/// line (responses may arrive in any order across batches).
fn run_workload(addr: SocketAddr, n: usize) -> BTreeMap<u64, String> {
    let names = ["gold", "silver", "bronze"];
    let images = synthetic_digits(n, 321);
    let mut c = Client::connect(addr);
    for (i, s) in images.iter().enumerate() {
        c.send(&render_infer_request(i as u64, names[i % names.len()], &s.pixels));
    }
    let mut out = BTreeMap::new();
    for _ in 0..n {
        let line = c.recv_line();
        let resp = parse_response(&line).unwrap();
        assert!(resp.ok, "{:?}", resp.error);
        assert!(out.insert(resp.id, line).is_none(), "duplicate id");
    }
    out
}

#[test]
fn responses_are_invariant_across_workers_and_batch_size() {
    let dir = tmp_dir("invariant");
    build_store(&dir, &[4, 8]);
    let tiers = "gold=0,silver=4,bronze=16";

    let sequential = start_server(Some(dir.as_path()), tiers, 1, 1);
    let first = run_workload(sequential.addr(), 42);
    let second = run_workload(sequential.addr(), 42);
    assert_eq!(first, second, "single-worker batch=1 must be deterministic");
    sequential.shutdown();
    sequential.join();

    let batched = start_server(Some(dir.as_path()), tiers, 4, 8);
    let third = run_workload(batched.addr(), 42);
    assert_eq!(
        first, third,
        "4 workers / batch 8 must produce byte-identical response lines"
    );
    batched.shutdown();
    batched.join();
    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn malformed_traffic_gets_structured_errors_and_serving_survives() {
    // No store: every tier resolves to the exact fallback.
    let server = start_server(None, "gold=0,silver=4", 2, 2);
    let mlp = serving_mlp();
    let img = &synthetic_digits(1, 9)[0];
    let mut c = Client::connect(server.addr());

    // Unknown tier.
    let resp = c.roundtrip(&render_infer_request(1, "platinum", &img.pixels));
    assert!(!resp.ok);
    assert!(resp.error.as_deref().unwrap().contains("unknown tier"), "{resp:?}");

    // Unknown bench.
    let resp = c.roundtrip(
        "{\"type\":\"infer\",\"id\":2,\"tier\":\"gold\",\"bench\":\"adder_i4\",\
         \"pixels\":[]}",
    );
    assert!(!resp.ok);
    assert!(resp.error.as_deref().unwrap().contains("unknown bench"), "{resp:?}");

    // Not JSON at all.
    let resp = c.roundtrip("this is not json");
    assert!(!resp.ok);

    // Wrong pixel count.
    let resp = c.roundtrip(&render_infer_request(3, "gold", &[1, 2, 3]));
    assert!(!resp.ok);
    assert!(resp.error.as_deref().unwrap().contains("64 pixels"), "{resp:?}");

    // Pixels outside the 4-bit range.
    let resp = c.roundtrip(
        "{\"type\":\"infer\",\"id\":4,\"tier\":\"gold\",\"pixels\":[99]}",
    );
    assert!(!resp.ok);

    // After all of that, the same connection and workers still serve.
    let resp = c.roundtrip(&render_infer_request(5, "gold", &img.pixels));
    assert!(resp.ok, "{:?}", resp.error);
    assert_eq!(
        resp.label,
        Some(mlp.infer(&img.pixels, &sxpat::nn::MultLut::exact()) as u64)
    );

    // Graceful shutdown via the wire protocol.
    let resp = c.roundtrip(&render_control_request("shutdown", 6));
    assert!(resp.ok);
    server.join();
}

#[test]
fn watch_streams_samples_and_survives_subscriber_disconnect() {
    use sxpat::obs::timeseries::Sample;
    use sxpat::serve::protocol::render_watch_request;

    // No store: exact fallback on every tier, fast startup.
    let server = start_server(None, "gold=0,silver=4", 2, 2);
    let img = &synthetic_digits(1, 11)[0];

    // A bounded subscription delivers exactly `count` samples, each a
    // parseable cumulative registry sample, then the connection keeps
    // answering ordinary requests (the sampler thread retired).
    let mut c = Client::connect(server.addr());
    let infer = c.roundtrip(&render_infer_request(1, "gold", &img.pixels));
    assert!(infer.ok);
    c.send(&render_watch_request(7, Some(10), Some(2)));
    let mut last_requests = 0;
    for _ in 0..2 {
        let push = c.recv();
        assert!(push.ok);
        assert_eq!(push.id, 7);
        let sample =
            Sample::from_json(push.raw.get("sample").expect("sample payload")).unwrap();
        assert_eq!(sample.node, "serve");
        // Counters on the wire are cumulative: the infer above is
        // visible, and successive pushes never go backwards.
        let req = sample
            .counters
            .get("pallas_serve_requests_total{tier=\"gold\"}")
            .copied()
            .unwrap_or(0);
        assert!(req >= 1, "cumulative sample missing the prior request");
        assert!(req >= last_requests);
        last_requests = req;
    }
    let stats = c.roundtrip(&render_control_request("stats", 8));
    assert!(stats.ok, "connection serves normally after the stream ends");

    // An *unbounded* subscriber that vanishes mid-stream must tear
    // down silently: the writer thread dies on the broken socket, the
    // sampler notices its channel is gone and exits, and the server
    // keeps serving everyone else.
    let mut doomed = Client::connect(server.addr());
    doomed.send(&render_watch_request(9, Some(5), None));
    let first = doomed.recv();
    assert!(first.ok, "stream started");
    drop(doomed); // disconnect with the subscription live

    // Give the teardown a moment, then prove the server is healthy.
    std::thread::sleep(Duration::from_millis(50));
    let resp = c.roundtrip(&render_infer_request(10, "silver", &img.pixels));
    assert!(resp.ok, "{:?}", resp.error);

    server.shutdown();
    server.join();
}

#[test]
fn reload_serves_new_operator_without_dropping_in_flight_requests() {
    let dir = tmp_dir("reload");
    build_store(&dir, &[8]);
    let server = start_server(Some(dir.as_path()), "silver=8", 2, 4);
    let images = synthetic_digits(10, 55);
    let mut c = Client::connect(server.addr());

    // Baseline: silver serves the swept MUSCAT operator.
    let before = c.roundtrip(&render_infer_request(1000, "silver", &images[0].pixels));
    assert!(before.ok);
    let before_src =
        before.raw.get("source").and_then(Json::as_str).unwrap().to_string();
    assert!(before_src.starts_with("oplib:MUSCAT:"), "{before_src}");

    // A strictly better operator lands in the WAL (as a concurrent
    // sweep would append it): lower achieved error AND smaller area.
    {
        let store = Store::open(&dir).unwrap();
        store.append(Fingerprint(0xBEEF), &masked_mult_record(3, 0.5)).unwrap();
    }

    // Pipeline: 5 infers, the reload, 5 more infers — every request is
    // answered (nothing dropped across the atomic swap).
    for (i, s) in images[..5].iter().enumerate() {
        c.send(&render_infer_request(i as u64, "silver", &s.pixels));
    }
    c.send(&render_control_request("reload", 77));
    for (i, s) in images[5..].iter().enumerate() {
        c.send(&render_infer_request(5 + i as u64, "silver", &s.pixels));
    }
    let mut infer_ok = 0;
    let mut reload_ok = false;
    for _ in 0..11 {
        let resp = c.recv();
        assert!(resp.ok, "{:?}", resp.error);
        if resp.id == 77 {
            assert!(
                resp.raw.get("info").and_then(Json::as_str).unwrap().contains("reload"),
            );
            reload_ok = true;
        } else {
            infer_ok += 1;
        }
    }
    assert_eq!(infer_ok, 10);
    assert!(reload_ok);

    // Post-reload, silver serves the new operator.
    let after = c.roundtrip(&render_infer_request(2000, "silver", &images[0].pixels));
    assert!(after.ok);
    assert_eq!(after.raw.get("area"), Some(&Json::Num(0.5)));
    let after_src = after.raw.get("source").and_then(Json::as_str).unwrap();
    assert!(after_src.starts_with("oplib:SHARED:"), "{after_src}");
    assert_ne!(after_src, before_src);

    server.shutdown();
    server.join();
    std::fs::remove_dir_all(&dir).unwrap();
}

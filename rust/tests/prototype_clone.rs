//! Prototype-clone equivalence suite: a cloned prototype miter must be
//! indistinguishable from a freshly built one — byte-identical models,
//! identical UNSAT/budget outcomes — for both templates on the paper's
//! i4 benchmarks. This is the contract the canonical parallel scan and
//! the sweep-level `MiterCache` rest on.

use sxpat::circuit::generators::benchmark_by_name;
use sxpat::circuit::sim::TruthTables;
use sxpat::sat::dimacs::{solver_from_dimacs, to_dimacs};
use sxpat::sat::SatResult;
use sxpat::search::{MiterCache, SearchConfig};
use sxpat::template::{NonsharedMiter, SharedMiter, SolveOutcome};

fn exact_of(name: &str) -> (Vec<u64>, usize, usize, u64) {
    let b = benchmark_by_name(name).unwrap();
    let nl = b.netlist();
    let exact = TruthTables::simulate(&nl).output_values(&nl);
    (exact, nl.n_inputs(), nl.n_outputs(), b.fig4_et())
}

#[test]
fn shared_clone_enumerates_byte_identical_models() {
    for name in ["adder_i4", "mult_i4"] {
        let (exact, n, m, et) = exact_of(name);
        let pool = 6;
        let mut fresh = SharedMiter::build(n, m, pool, &exact, et);
        let proto = SharedMiter::build(n, m, pool, &exact, et);
        let mut cloned = proto.clone();
        // Same restriction, multi-model enumeration with blocking: the
        // two must stay in lockstep until UNSAT.
        let (pit, its) = (3, 6);
        for round in 0..4 {
            let a = fresh.solve(pit, its);
            let b = cloned.solve(pit, its);
            assert_eq!(a, b, "{name} round {round}");
            match (a, b) {
                (SolveOutcome::Sat(pa), SolveOutcome::Sat(pb)) => {
                    assert_eq!(pa, pb, "{name} round {round}: model mismatch");
                    fresh.block(&pa);
                    cloned.block(&pb);
                }
                _ => break,
            }
        }
    }
}

#[test]
fn xpat_clone_enumerates_byte_identical_models() {
    for name in ["adder_i4", "mult_i4"] {
        let (exact, n, m, et) = exact_of(name);
        let k = 3;
        let mut fresh = NonsharedMiter::build(n, m, k, &exact, et);
        let proto = NonsharedMiter::build(n, m, k, &exact, et);
        let mut cloned = proto.clone();
        let (lpp, ppo) = (3, 2);
        for round in 0..4 {
            let a = fresh.solve(lpp, ppo);
            let b = cloned.solve(lpp, ppo);
            assert_eq!(a, b, "{name} round {round}");
            match (a, b) {
                (SolveOutcome::Sat(pa), SolveOutcome::Sat(pb)) => {
                    assert_eq!(pa, pb, "{name} round {round}: model mismatch");
                    fresh.block(&pa);
                    cloned.block(&pb);
                }
                _ => break,
            }
        }
    }
}

#[test]
fn clone_reproduces_minimized_and_unsat_outcomes() {
    let (exact, n, m, et) = exact_of("mult_i4");
    let mut fresh = SharedMiter::build(n, m, 6, &exact, et);
    let proto = SharedMiter::build(n, m, 6, &exact, et);
    let mut cloned = proto.clone();
    // Proxy-minimised first model (the per-cell hot path).
    assert_eq!(fresh.solve_minimized(4, 8), cloned.solve_minimized(4, 8));
    // A cell tight enough to be UNSAT must be UNSAT on both.
    assert_eq!(fresh.solve(0, 0), SolveOutcome::Unsat);
    assert_eq!(cloned.solve(0, 0), SolveOutcome::Unsat);
}

#[test]
fn clone_reproduces_budget_outcomes() {
    // Identical conflict budgets must abort (or not) identically: the
    // cloned solver replays the same trace, conflict for conflict.
    let (exact, n, m, _) = exact_of("mult_i4");
    let fresh = SharedMiter::build(n, m, 6, &exact, 0);
    let proto = SharedMiter::build(n, m, 6, &exact, 0);
    let cloned = proto.clone();
    for budget in [0u64, 5, 50, 500] {
        let mut f = fresh.clone();
        let mut c = cloned.clone();
        f.set_conflict_budget(Some(budget));
        c.set_conflict_budget(Some(budget));
        let (fa, ca) = (f.solve(2, 4), c.solve(2, 4));
        assert_eq!(fa, ca, "budget {budget}");
    }
}

#[test]
fn preprocessed_clone_pair_is_byte_identical() {
    // The amortisation contract of prototype-time preprocessing: a clone
    // of a preprocessed prototype must replay *exactly* what a fresh
    // build-then-preprocess does — same models and, stronger, the same
    // search trace (conflicts / propagations / restarts) and the same
    // preprocessing work.
    for name in ["adder_i4", "mult_i4"] {
        let (exact, n, m, et) = exact_of(name);
        let mut fresh = SharedMiter::build(n, m, 6, &exact, et);
        fresh.preprocess();
        let mut proto = SharedMiter::build(n, m, 6, &exact, et);
        proto.preprocess();
        let mut cloned = proto.clone();
        for round in 0..4 {
            let a = fresh.solve(3, 6);
            let b = cloned.solve(3, 6);
            assert_eq!(a, b, "{name} round {round}");
            match (a, b) {
                (SolveOutcome::Sat(pa), SolveOutcome::Sat(pb)) => {
                    assert_eq!(pa, pb, "{name} round {round}: model mismatch");
                    fresh.block(&pa);
                    cloned.block(&pb);
                }
                _ => break,
            }
        }
        let (fs, cs) = (&fresh.b.solver.stats, &cloned.b.solver.stats);
        assert_eq!(fs.conflicts, cs.conflicts, "{name}: conflict trace diverged");
        assert_eq!(fs.propagations, cs.propagations, "{name}");
        assert_eq!(fs.restarts, cs.restarts, "{name}");
        assert_eq!(fs.restarts_blocked, cs.restarts_blocked, "{name}");
        assert_eq!(fs.preprocess_probes, cs.preprocess_probes, "{name}");
        assert_eq!(fs.preprocess_subsumed, cs.preprocess_subsumed, "{name}");
        assert!(fs.preprocess_probes > 0, "{name}: preprocessing must do work");
    }
}

#[test]
fn preprocessed_search_is_worker_count_invariant() {
    // End-to-end determinism with the new heuristics on by default: the
    // cached (preprocessed) prototype path must give the same result on
    // 1 and 4 cell workers — same best area across the two scan modes
    // (the engine's 1-vs-N contract), and byte-identical full outcomes
    // (cells, models, areas) across canonical worker counts.
    let bench = benchmark_by_name("adder_i4").unwrap();
    let nl = bench.netlist();
    let et = bench.fig4_et();
    let cfg_for = |workers: usize| SearchConfig {
        pool: 5,
        solutions_per_cell: 2,
        max_sat_cells: 2,
        conflict_budget: None,
        time_budget_ms: 120_000,
        cell_workers: workers,
        ..Default::default()
    };
    let cache = MiterCache::new();
    let single = cache.search_shared(&nl, et, &cfg_for(1));
    let parallel = cache.search_shared(&nl, et, &cfg_for(4));
    let a = single.best().expect("1-worker scan found no solution").area;
    let b = parallel.best().expect("4-worker scan found no solution").area;
    assert!((a - b).abs() < 1e-9, "1-worker best {a} vs 4-worker best {b}");
    // Canonical counts (> 1) pin the *full* outcome, models included.
    let again = cache.search_shared(&nl, et, &cfg_for(2));
    let key = |o: &sxpat::search::SearchOutcome| {
        (
            o.cells_tried,
            o.cells_sat,
            o.cells_unsat,
            o.solutions
                .iter()
                .map(|s| (s.cell, s.params.clone(), s.area))
                .collect::<Vec<_>>(),
        )
    };
    assert_eq!(key(&again), key(&parallel), "2 vs 4 workers diverged");
}

#[test]
fn dumped_dimacs_cell_agrees_with_the_miter() {
    // The --dump-cnf surface: base CNF + restriction units must give an
    // external solver exactly the miter's answer. We stand in for the
    // external solver with a fresh Solver over the round-tripped DIMACS.
    let (exact, n, m, et) = exact_of("adder_i4");
    for (pit, its) in [(0usize, 0usize), (2, 4), (8, 24)] {
        let mut miter = SharedMiter::build(n, m, 8, &exact, et);
        let mut clauses = miter.b.solver.export_clauses();
        clauses.extend(miter.restrict(pit, its).into_iter().map(|l| vec![l]));
        let dimacs = to_dimacs(miter.b.solver.n_vars(), &clauses);
        let (mut reference, ok) = solver_from_dimacs(&dimacs).unwrap();
        let ref_result = if ok { reference.solve(&[]) } else { SatResult::Unsat };
        let want_sat = miter.solve(pit, its).is_sat();
        assert_eq!(
            ref_result == SatResult::Sat,
            want_sat,
            "cell ({pit}, {its}) disagrees with the DIMACS export"
        );
    }
}

//! The observability fabric's core contract: instrumentation is
//! observe-only. A traced sweep must produce records, fig5 CSV and WAL
//! bytes identical to an untraced one (modulo the `elapsed_ms`/`cached`
//! provenance pair, which reports wall clocks) — at 1 and 4 cell
//! workers — and a traced distributed run's merged multi-node trace
//! must validate and account for every committed job exactly once.
//! Also pins the serve `metrics` verb: the snapshot parses as
//! `util::Json` and its counters increase monotonically. Part of the
//! tier-1 test path (plain `cargo test`).

use std::collections::BTreeMap;
use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::path::{Path, PathBuf};
use std::time::Duration;

use sxpat::circuit::generators::benchmark_by_name;
use sxpat::coordinator::{run_sweep_obs, run_sweep_stored, Method, RunRecord, SweepPlan};
use sxpat::dist::{run_worker, Coordinator, DistConfig, WorkerConfig};
use sxpat::obs::{trace, Obs};
use sxpat::report::fig5_csv;
use sxpat::search::SearchConfig;
use sxpat::serve::protocol::{parse_response, render_control_request, render_infer_request};
use sxpat::serve::{parse_tiers, serving_mlp, Registry, ServeConfig, Server};
use sxpat::store::Store;
use sxpat::util::Json;

fn tmp_dir(tag: &str) -> PathBuf {
    let d = std::env::temp_dir()
        .join(format!("sxpat_obs_it_{tag}_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&d);
    std::fs::create_dir_all(&d).unwrap();
    d
}

fn tiny_plan(cell_workers: usize) -> SweepPlan {
    SweepPlan {
        benches: vec![benchmark_by_name("adder_i4").unwrap()],
        methods: vec![Method::Shared, Method::Muscat],
        ets: Some(vec![1, 2]),
        search: SearchConfig {
            pool: 5,
            solutions_per_cell: 1,
            max_sat_cells: 1,
            conflict_budget: Some(20_000),
            time_budget_ms: 20_000,
            cell_workers,
            ..Default::default()
        },
        workers: 1,
    }
}

/// Everything that must agree between a traced and an untraced run
/// (all fields except the wall-clock `elapsed_ms`).
fn result_key(r: &RunRecord) -> impl PartialEq + std::fmt::Debug {
    (
        r.bench,
        r.method,
        r.et,
        r.area.to_bits(),
        r.max_err,
        r.mean_err.to_bits(),
        r.proxy,
        r.values.clone(),
        r.all_points.len(),
        r.cached,
        r.error.clone(),
    )
}

/// The WAL with every record's `elapsed_ms` zeroed — the only field
/// two runs of the same jobs may legitimately differ in.
fn normalized_wal(dir: &Path) -> Vec<String> {
    let text = std::fs::read_to_string(dir.join("wal.jsonl")).unwrap();
    text.lines()
        .map(|l| {
            let j = Json::parse(l).unwrap();
            let fp = j.get("fp").and_then(Json::as_str).unwrap().to_string();
            let mut rec = RunRecord::from_json(j.get("record").unwrap()).unwrap();
            rec.elapsed_ms = 0;
            let mut m = BTreeMap::new();
            m.insert("fp".to_string(), Json::Str(fp));
            m.insert("record".to_string(), rec.to_json());
            Json::Obj(m).render()
        })
        .collect()
}

/// The tentpole invariant: with tracing ON, the sweep's outputs are
/// byte-identical to tracing OFF — records, fig5 CSV, and the WAL —
/// at both 1 and 4 cell workers. The trace itself must be non-trivial
/// and pass `trace --check`'s validation.
#[test]
fn traced_sweep_outputs_match_untraced_baseline() {
    for cell_workers in [1usize, 4] {
        let plan = tiny_plan(cell_workers);

        let base_dir = tmp_dir(&format!("base_cw{cell_workers}"));
        let base = {
            let store = Store::open(&base_dir).unwrap();
            run_sweep_stored(&plan, Some(&store))
        };
        assert!(base.iter().all(|r| r.error.is_none() && !r.cached));

        let traced_dir = tmp_dir(&format!("traced_cw{cell_workers}"));
        let trace_path = traced_dir.join("sweep.trace.jsonl");
        let traced = {
            let store = Store::open(&traced_dir).unwrap();
            let obs = Obs::to_file(&trace_path, "sweep");
            let records = run_sweep_obs(&plan, Some(&store), &obs);
            obs.flush().unwrap();
            records
        };

        // Record-set equality, modulo the wall clock.
        assert_eq!(base.len(), traced.len());
        for (a, b) in base.iter().zip(&traced) {
            assert_eq!(result_key(a), result_key(b), "cell_workers={cell_workers}");
        }
        // fig5 CSV byte-identical (both runs are fresh: cached=false).
        assert_eq!(fig5_csv(&base), fig5_csv(&traced));
        // WAL byte-identical modulo elapsed_ms, including line order.
        assert_eq!(normalized_wal(&base_dir), normalized_wal(&traced_dir));

        // The trace is real: it loads, validates, and contains the
        // per-job and per-cell solve spans.
        let events = trace::load(&trace_path).unwrap();
        let report = trace::check(&events).unwrap();
        assert!(report.events > 0);
        assert!(report.spans > 0);
        assert_eq!(report.nodes, vec!["sweep".to_string()]);
        assert!(events.iter().any(|e| e.kind == "span_end" && e.name == "sweep.job"));
        assert!(events.iter().any(|e| e.kind == "span_end" && e.name == "sweep.cell"));
        // Cell spans fold solver-effort deltas (the SHARED jobs hit SAT).
        assert!(events
            .iter()
            .filter(|e| e.kind == "span_end" && e.name == "sweep.cell")
            .any(|e| e.fields.contains_key("conflicts") && e.fields.contains_key("status")));
        // Causality: cell spans nest under their job span.
        let job_ids: std::collections::BTreeSet<u64> = events
            .iter()
            .filter(|e| e.kind == "span_begin" && e.name == "sweep.job")
            .filter_map(|e| e.fields.get("span").and_then(Json::as_u64))
            .collect();
        assert!(!job_ids.is_empty());
        assert!(
            events
                .iter()
                .filter(|e| e.kind == "span_begin" && e.name == "sweep.cell")
                .all(|e| {
                    e.fields
                        .get("parent")
                        .and_then(Json::as_u64)
                        .is_some_and(|p| job_ids.contains(&p))
                }),
            "every sweep.cell parents under a sweep.job"
        );
        assert!(report.parented > 0);

        std::fs::remove_dir_all(&base_dir).unwrap();
        std::fs::remove_dir_all(&traced_dir).unwrap();
    }
}

/// A traced 2-worker distributed run: results still match the
/// untraced local baseline, and the merged coordinator + worker trace
/// validates with every committed job accounted for exactly once.
#[test]
fn traced_distributed_run_merges_and_accounts_every_commit_once() {
    let plan = tiny_plan(1);

    let base_dir = tmp_dir("dbase");
    let base = {
        let store = Store::open(&base_dir).unwrap();
        run_sweep_stored(&plan, Some(&store))
    };

    let dist_dir = tmp_dir("dtraced");
    let coord_trace = dist_dir.join("coord.trace.jsonl");
    let worker_traces: Vec<PathBuf> =
        (0..2).map(|i| dist_dir.join(format!("w{i}.trace.jsonl"))).collect();

    let store = Store::open(&dist_dir).unwrap();
    let cfg = DistConfig {
        addr: "127.0.0.1:0".to_string(),
        lease_ms: 60_000,
        wait_ms: 25,
        obs: Obs::to_file(&coord_trace, "coord"),
    };
    let records = std::thread::scope(|s| {
        let coord = Coordinator::bind(&plan, Some(&store), &cfg).unwrap();
        let addr = coord.addr();
        let run = s.spawn(move || coord.run().unwrap());
        let workers: Vec<_> = worker_traces
            .iter()
            .enumerate()
            .map(|(i, path)| {
                let cfg = WorkerConfig {
                    addr: addr.to_string(),
                    name: format!("w{i}"),
                    cell_workers: None,
                    max_jobs: None,
                    obs: Obs::to_file(path, &format!("w{i}")),
                };
                s.spawn(move || run_worker(&cfg).unwrap())
            })
            .collect();
        let records = run.join().unwrap();
        for w in workers {
            let _ = w.join().unwrap();
        }
        records
    });

    // Observe-only under distribution too: the traced distributed run
    // matches the untraced local baseline byte for byte (modulo clock).
    assert_eq!(records.len(), plan.n_jobs());
    for (a, b) in base.iter().zip(&records) {
        assert_eq!(a.bench, b.bench);
        assert_eq!(a.area.to_bits(), b.area.to_bits());
        assert_eq!(a.values, b.values);
        assert_eq!(a.error, b.error);
    }
    assert_eq!(normalized_wal(&base_dir), normalized_wal(&dist_dir));

    // Merge all three node dumps: the multi-node view must validate,
    // span worker solve spans, and commit every job exactly once.
    let mut events = trace::load(&coord_trace).unwrap();
    for path in &worker_traces {
        events.extend(trace::load(path).unwrap());
    }
    let report = trace::check(&events).unwrap();
    assert_eq!(report.nodes.len(), 3, "coord + 2 workers");
    assert!(events.iter().any(|e| e.kind == "span_end" && e.name == "dist.job"));

    // The acceptance bar for causal propagation: every worker-side
    // dist.job span is parented under a coordinator dist.lease span,
    // across the process boundary.
    let lease_ids: std::collections::BTreeSet<u64> = events
        .iter()
        .filter(|e| e.node == "coord" && e.kind == "span_begin" && e.name == "dist.lease")
        .filter_map(|e| e.fields.get("span").and_then(Json::as_u64))
        .collect();
    assert!(!lease_ids.is_empty(), "coordinator opened lease spans");
    let jobs_spans: Vec<_> = events
        .iter()
        .filter(|e| e.kind == "span_begin" && e.name == "dist.job")
        .collect();
    assert!(!jobs_spans.is_empty());
    for e in &jobs_spans {
        assert_eq!(
            e.fields.get("parent_node").and_then(Json::as_str),
            Some("coord"),
            "dist.job on {} parents across nodes: {:?}",
            e.node,
            e.fields
        );
        let p = e.fields.get("parent").and_then(Json::as_u64).unwrap();
        assert!(lease_ids.contains(&p), "parent {p} is a dist.lease span");
    }
    assert!(report.parented >= jobs_spans.len());

    let commits = trace::commit_counts(&events);
    assert_eq!(commits.len(), plan.n_jobs(), "every job committed");
    assert!(
        commits.values().all(|&c| c == 1),
        "each job exactly once: {commits:?}"
    );
    // Job indices are dense 0..n_jobs.
    let jobs: Vec<u64> = commits.keys().copied().collect();
    assert_eq!(jobs, (0..plan.n_jobs() as u64).collect::<Vec<_>>());

    drop(store);
    std::fs::remove_dir_all(&base_dir).unwrap();
    std::fs::remove_dir_all(&dist_dir).unwrap();
}

fn counter_value(metrics: &Json, name: &str) -> u64 {
    metrics
        .get("counters")
        .and_then(|c| c.get(name))
        .and_then(Json::as_u64)
        .unwrap_or(0)
}

/// The serve `metrics` verb: the response line is valid `util::Json`,
/// the registry snapshot has the counters/gauges shape, and counters
/// increase monotonically across requests.
#[test]
fn serve_metrics_snapshot_is_valid_json_and_monotonic() {
    // No store: every tier serves the exact multiplier — cheap, and
    // the metrics plumbing is identical.
    let registry = Registry::open(
        "mult_i8",
        parse_tiers("gold=0,silver=4").unwrap(),
        None,
        std::sync::Arc::new(serving_mlp()),
        true,
    )
    .unwrap();
    let server = Server::start(
        &ServeConfig {
            addr: "127.0.0.1:0".to_string(),
            workers: 2,
            batch: 4,
            batch_wait_ms: 2,
            queue_cap: 64,
            ..Default::default()
        },
        registry,
    )
    .unwrap();

    let stream = TcpStream::connect(server.addr()).unwrap();
    stream.set_read_timeout(Some(Duration::from_secs(60))).unwrap();
    let mut writer = stream.try_clone().unwrap();
    let mut reader = BufReader::new(stream);
    let mut roundtrip = |req: &str| -> String {
        writer.write_all(req.as_bytes()).unwrap();
        writer.write_all(b"\n").unwrap();
        let mut line = String::new();
        let n = reader.read_line(&mut line).unwrap();
        assert!(n > 0, "server closed the connection");
        line.trim().to_string()
    };

    let snap = |line: &str| -> Json {
        // The whole response line must parse as our own Json.
        let j = Json::parse(line).unwrap();
        assert_eq!(j.get("ok").and_then(Json::as_bool), Some(true));
        let m = j.get("metrics").unwrap().clone();
        assert!(m.get("counters").is_some(), "snapshot has counters: {line}");
        assert!(m.get("gauges").is_some(), "snapshot has gauges: {line}");
        m
    };

    let first = snap(&roundtrip(&render_control_request("metrics", 1)));
    let gold_before = counter_value(&first, "pallas_serve_requests_total{tier=\"gold\"}");
    assert!(
        counter_value(&first, "pallas_serve_connections_total") >= 1,
        "this very connection is counted"
    );

    let pixels: Vec<u8> = (0..64).map(|i| (i % 16) as u8).collect();
    for k in 0..3u64 {
        let resp =
            parse_response(&roundtrip(&render_infer_request(100 + k, "gold", &pixels)))
                .unwrap();
        assert!(resp.ok, "infer failed: {:?}", resp.error);
    }

    let second = snap(&roundtrip(&render_control_request("metrics", 2)));
    let gold_after = counter_value(&second, "pallas_serve_requests_total{tier=\"gold\"}");
    assert!(
        gold_after >= gold_before + 3,
        "gold tier counter is monotonic: {gold_before} -> {gold_after}"
    );

    let _ = roundtrip(&render_control_request("shutdown", 3));
    server.join();
}

/// The serve-side observe-only contract: a `--trace`d server answers
/// the exact same byte stream an untraced one does, and its trace
/// validates with `serve.queue` spans nested under their
/// `serve.request` and `serve.compute` under `serve.batch`.
#[test]
fn traced_serve_responses_match_untraced_baseline() {
    let start = |obs: Obs| -> Server {
        let registry = Registry::open(
            "mult_i8",
            parse_tiers("gold=0,silver=4").unwrap(),
            None,
            std::sync::Arc::new(serving_mlp()),
            true,
        )
        .unwrap();
        Server::start(
            &ServeConfig {
                addr: "127.0.0.1:0".to_string(),
                workers: 2,
                batch: 4,
                batch_wait_ms: 2,
                queue_cap: 64,
                obs,
            },
            registry,
        )
        .unwrap()
    };
    // One connection, strictly sequential round trips, so the response
    // order (and therefore the byte stream) is deterministic.
    let drive = |server: Server| -> Vec<String> {
        let stream = TcpStream::connect(server.addr()).unwrap();
        stream.set_read_timeout(Some(Duration::from_secs(60))).unwrap();
        let mut writer = stream.try_clone().unwrap();
        let mut reader = BufReader::new(stream);
        let pixels: Vec<u8> = (0..64).map(|i| (i * 5 % 16) as u8).collect();
        let mut lines = Vec::new();
        for k in 0..8u64 {
            let tier = if k % 2 == 0 { "gold" } else { "silver" };
            writer
                .write_all(render_infer_request(k, tier, &pixels).as_bytes())
                .unwrap();
            writer.write_all(b"\n").unwrap();
            let mut line = String::new();
            assert!(reader.read_line(&mut line).unwrap() > 0);
            lines.push(line.trim().to_string());
        }
        writer
            .write_all(render_control_request("shutdown", 99).as_bytes())
            .unwrap();
        writer.write_all(b"\n").unwrap();
        let mut line = String::new();
        let _ = reader.read_line(&mut line);
        server.join();
        lines
    };

    let base = drive(start(Obs::off()));

    let dir = tmp_dir("serve_traced");
    let trace_path = dir.join("serve.trace.jsonl");
    let traced = drive(start(Obs::to_file(&trace_path, "serve")));
    assert_eq!(base, traced, "tracing must not change a single response byte");

    let events = trace::load(&trace_path).unwrap();
    let report = trace::check(&events).unwrap();
    assert_eq!(report.nodes, vec!["serve".to_string()]);
    assert!(report.parented > 0);
    for name in ["serve.request", "serve.queue", "serve.batch", "serve.compute"] {
        assert!(
            events.iter().any(|e| e.kind == "span_end" && e.name == name),
            "trace contains {name} spans"
        );
    }
    let ids = |name: &str| -> std::collections::BTreeSet<u64> {
        events
            .iter()
            .filter(|e| e.kind == "span_begin" && e.name == name)
            .filter_map(|e| e.fields.get("span").and_then(Json::as_u64))
            .collect()
    };
    let parents = |name: &str| -> Vec<u64> {
        events
            .iter()
            .filter(|e| e.kind == "span_begin" && e.name == name)
            .map(|e| e.fields.get("parent").and_then(Json::as_u64).unwrap())
            .collect()
    };
    let req_ids = ids("serve.request");
    assert!(parents("serve.queue").iter().all(|p| req_ids.contains(p)));
    let batch_ids = ids("serve.batch");
    assert!(parents("serve.compute").iter().all(|p| batch_ids.contains(p)));

    std::fs::remove_dir_all(&dir).unwrap();
}

/// The live-telemetry extension of the observe-only contract
/// (DESIGN.md §14): a server with an active `watch` subscriber
/// answers the exact same byte stream an unwatched one does — the
/// sampler thread only ever *reads* the registry.
#[test]
fn watched_serve_responses_match_unwatched_baseline() {
    use sxpat::serve::protocol::render_watch_request;

    let start = || -> Server {
        let registry = Registry::open(
            "mult_i8",
            parse_tiers("gold=0,silver=4").unwrap(),
            None,
            std::sync::Arc::new(serving_mlp()),
            true,
        )
        .unwrap();
        Server::start(
            &ServeConfig {
                addr: "127.0.0.1:0".to_string(),
                workers: 2,
                batch: 4,
                batch_wait_ms: 2,
                queue_cap: 64,
                sample_ms: 5,
                ..Default::default()
            },
            registry,
        )
        .unwrap()
    };
    // Same strictly-sequential discipline as the traced test: one
    // connection, one round trip at a time.
    let drive = |server: &Server| -> Vec<String> {
        let stream = TcpStream::connect(server.addr()).unwrap();
        stream.set_read_timeout(Some(Duration::from_secs(60))).unwrap();
        let mut writer = stream.try_clone().unwrap();
        let mut reader = BufReader::new(stream);
        let pixels: Vec<u8> = (0..64).map(|i| (i * 3 % 16) as u8).collect();
        let mut lines = Vec::new();
        for k in 0..8u64 {
            let tier = if k % 2 == 0 { "gold" } else { "silver" };
            writer
                .write_all(render_infer_request(k, tier, &pixels).as_bytes())
                .unwrap();
            writer.write_all(b"\n").unwrap();
            let mut line = String::new();
            assert!(reader.read_line(&mut line).unwrap() > 0);
            lines.push(line.trim().to_string());
        }
        lines
    };

    let baseline_server = start();
    let base = drive(&baseline_server);
    baseline_server.shutdown();
    baseline_server.join();

    let watched_server = start();
    // A live watch subscription on its own connection, pushing every
    // 5 ms for the whole workload.
    let watcher = TcpStream::connect(watched_server.addr()).unwrap();
    watcher.set_read_timeout(Some(Duration::from_secs(60))).unwrap();
    let mut wtx = watcher.try_clone().unwrap();
    wtx.write_all(render_watch_request(1, Some(5), None).as_bytes()).unwrap();
    wtx.write_all(b"\n").unwrap();
    let mut wrx = BufReader::new(watcher);
    let mut first_push = String::new();
    assert!(wrx.read_line(&mut first_push).unwrap() > 0, "stream started");

    let watched = drive(&watched_server);
    assert_eq!(
        base, watched,
        "an active watch subscription must not change a single response byte"
    );
    drop(wtx);
    drop(wrx);
    watched_server.shutdown();
    watched_server.join();
}

//! Persistent-store integration: the resumable-sweep contract end to
//! end — solve everything via SAT once, serve 100% from disk on the
//! rerun with byte-identical figures (modulo the cached/elapsed
//! columns), survive crash-torn WALs, and serve sound operators out of
//! the library. Part of the tier-1 test path (plain `cargo test`).

use std::path::PathBuf;

use sxpat::circuit::generators::benchmark_by_name;
use sxpat::circuit::sim::TruthTables;
use sxpat::coordinator::{run_sweep_stored, Method, RunRecord, SweepPlan};
use sxpat::nn::MultLut;
use sxpat::report::fig5_csv;
use sxpat::search::SearchConfig;
use sxpat::store::{job_fingerprint, OpLib, Store};

fn tmp_dir(tag: &str) -> PathBuf {
    let d = std::env::temp_dir()
        .join(format!("sxpat_store_it_{tag}_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&d);
    d
}

fn tiny_plan() -> SweepPlan {
    SweepPlan {
        benches: vec![benchmark_by_name("adder_i4").unwrap()],
        methods: vec![Method::Shared, Method::Muscat],
        ets: Some(vec![1, 2]),
        search: SearchConfig {
            pool: 5,
            solutions_per_cell: 1,
            max_sat_cells: 1,
            conflict_budget: Some(20_000),
            time_budget_ms: 20_000,
            ..Default::default()
        },
        workers: 2,
    }
}

/// Everything that must survive the store round trip (all fields except
/// the provenance pair `elapsed_ms`/`cached`).
fn result_key(r: &RunRecord) -> impl PartialEq + std::fmt::Debug {
    (
        r.bench,
        r.method,
        r.et,
        r.area.to_bits(),
        r.max_err,
        r.mean_err.to_bits(),
        r.proxy,
        r.values.clone(),
        r.all_points.len(),
        r.error.clone(),
    )
}

/// Drop the trailing `cached` column from every fig5 CSV row.
fn strip_cached_column(csv: &str) -> String {
    csv.lines()
        .map(|l| match l.rsplit_once(',') {
            Some((head, _)) => head.to_string(),
            None => l.to_string(),
        })
        .collect::<Vec<_>>()
        .join("\n")
}

#[test]
fn second_sweep_is_served_entirely_from_the_store() {
    let dir = tmp_dir("resume");
    let plan = tiny_plan();

    let store = Store::open(&dir).unwrap();
    let fresh = run_sweep_stored(&plan, Some(&store));
    assert!(fresh.iter().all(|r| r.error.is_none()));
    assert!(
        fresh.iter().all(|r| !r.cached),
        "first run must solve everything via SAT"
    );
    assert_eq!(store.len(), fresh.len(), "every job committed to the WAL");
    drop(store);

    // Fresh process over the same dir: 100% store hits, zero solves.
    let store = Store::open(&dir).unwrap();
    let resumed = run_sweep_stored(&plan, Some(&store));
    assert_eq!(resumed.len(), fresh.len());
    assert!(
        resumed.iter().all(|r| r.cached),
        "second run must serve every job from the store"
    );
    assert!(resumed.iter().all(|r| r.elapsed_ms == 0));
    for (a, b) in fresh.iter().zip(&resumed) {
        assert_eq!(result_key(a), result_key(b));
    }

    // The acceptance bar: byte-identical fig5 CSVs modulo `cached`.
    assert_eq!(
        strip_cached_column(&fig5_csv(&fresh)),
        strip_cached_column(&fig5_csv(&resumed))
    );
    assert_ne!(fig5_csv(&fresh), fig5_csv(&resumed), "cached column differs");

    // No duplicate WAL lines were appended by the resumed run.
    assert_eq!(store.lines(), fresh.len());
    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn worker_count_does_not_change_store_keys() {
    // The fingerprint contract at the sweep level: a store written by a
    // 1-worker sweep serves a 4-cell-worker sweep of the same grid.
    let dir = tmp_dir("workers");
    let mut plan = tiny_plan();
    plan.search.cell_workers = 1;

    let store = Store::open(&dir).unwrap();
    let first = run_sweep_stored(&plan, Some(&store));

    plan.search.cell_workers = 4;
    plan.workers = 1;
    let second = run_sweep_stored(&plan, Some(&store));
    assert!(
        second.iter().all(|r| r.cached),
        "cell_workers must not key the store"
    );
    for (a, b) in first.iter().zip(&second) {
        assert_eq!(a.area.to_bits(), b.area.to_bits());
    }

    // A different ET grid does miss.
    plan.ets = Some(vec![1, 2, 3]);
    let third = run_sweep_stored(&plan, Some(&store));
    assert!(third.iter().filter(|r| r.et == 3).all(|r| !r.cached));
    assert!(third.iter().filter(|r| r.et != 3).all(|r| r.cached));
    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn torn_wal_tail_resumes_with_partial_credit() {
    // Crash mid-sweep: the WAL holds N good lines plus a torn tail. The
    // resumed sweep serves the good jobs and re-solves the torn one.
    let dir = tmp_dir("torn");
    let plan = tiny_plan();
    {
        let store = Store::open(&dir).unwrap();
        run_sweep_stored(&plan, Some(&store));
    }
    let wal = dir.join("wal.jsonl");
    let text = std::fs::read_to_string(&wal).unwrap();
    let n_lines = text.lines().count();
    // Tear the last line in half.
    let keep = text.len() - text.lines().last().unwrap().len() / 2 - 1;
    std::fs::write(&wal, &text[..keep]).unwrap();

    let store = Store::open(&dir).unwrap();
    assert_eq!(store.len(), n_lines - 1, "torn tail dropped");
    let resumed = run_sweep_stored(&plan, Some(&store));
    assert_eq!(resumed.iter().filter(|r| r.cached).count(), n_lines - 1);
    assert_eq!(resumed.iter().filter(|r| !r.cached).count(), 1);
    assert!(resumed.iter().all(|r| r.error.is_none()));
    // And now the store is whole again.
    assert_eq!(store.len(), n_lines);
    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn oplib_best_serves_min_area_sound_operator() {
    let dir = tmp_dir("oplib");
    let plan = tiny_plan();
    let store = Store::open(&dir).unwrap();
    let records = run_sweep_stored(&plan, Some(&store));

    let lib = OpLib::from_store(&store);
    let bench = benchmark_by_name("adder_i4").unwrap();
    for et in [1u64, 2] {
        let entry = lib.best("adder_i4", et).expect("stored operator expected");
        // Minimum area over every stored record whose achieved error
        // fits the budget.
        let min_area = records
            .iter()
            .filter(|r| r.max_err <= et && r.area.is_finite())
            .map(|r| r.area)
            .fold(f64::INFINITY, f64::min);
        assert_eq!(entry.area, min_area, "et={et}");

        // The exported truth table re-verifies against the oracle.
        OpLib::verify(entry).unwrap();
        let nl = bench.netlist();
        let exact = TruthTables::simulate(&nl).output_values(&nl);
        assert!(exact
            .iter()
            .zip(&entry.values)
            .all(|(&e, &a)| e.abs_diff(a) <= et));

        // And round-trips through the portable .tt text format.
        let tt = OpLib::export_tt(entry);
        assert_eq!(OpLib::parse_tt(&tt).unwrap(), entry.values);
    }
    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn oplib_values_drop_into_a_multlut() {
    // The NN-serving path on the real 4x4 multiplier geometry: sweep
    // mult_i8 with the fast sound baseline, pull the best operator for
    // an ET-8 budget from the library, build a MultLut from it.
    let dir = tmp_dir("multlut");
    let plan = SweepPlan {
        benches: vec![benchmark_by_name("mult_i8").unwrap()],
        methods: vec![Method::Muscat],
        ets: Some(vec![4, 8]),
        search: SearchConfig::default(),
        workers: 2,
    };
    let store = Store::open(&dir).unwrap();
    run_sweep_stored(&plan, Some(&store));

    let lib = OpLib::from_store(&store);
    let entry = lib.best("mult_i8", 8).expect("mult_i8 operator expected");
    OpLib::verify(entry).unwrap();
    let lut = MultLut::from_values(&entry.values);
    assert!(u64::from(lut.max_error()) <= 8);
    assert_eq!(u64::from(lut.max_error()), entry.max_err, "LUT error = recorded error");
    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn tampered_store_record_is_rejected_and_resolved() {
    // The oracle re-check on the serve path: a stored record whose
    // operator table no longer verifies (bit-rot, hand-editing) must be
    // re-solved, not served — and the fresh solve heals the store via
    // last-writer-wins.
    let dir = tmp_dir("tamper");
    let plan = tiny_plan();
    let store = Store::open(&dir).unwrap();
    let fresh = run_sweep_stored(&plan, Some(&store));

    // Overwrite one job's record with an unsound operator table.
    let job = &plan.jobs()[0];
    let nl = job.bench.netlist();
    let exact = TruthTables::simulate(&nl).output_values(&nl);
    let fp = job_fingerprint(
        nl.n_inputs(),
        nl.n_outputs(),
        &exact,
        job.method,
        job.et,
        &job.search,
    );
    let mut bad = store.get(fp).unwrap();
    bad.values[0] += 1000;
    store.append(fp, &bad).unwrap();

    let resumed = run_sweep_stored(&plan, Some(&store));
    assert!(!resumed[0].cached, "tampered record must be re-solved");
    assert!(resumed[1..].iter().all(|r| r.cached), "others still serve");
    assert_eq!(resumed[0].area.to_bits(), fresh[0].area.to_bits());
    // Healed: the store's copy verifies again.
    let healed = store.get(fp).unwrap();
    let et = job.et;
    assert!(exact.iter().zip(&healed.values).all(|(&e, &a)| e.abs_diff(a) <= et));
    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn fingerprints_match_between_sweep_and_direct_computation() {
    // The sweep and an external tool (e.g. a future serving daemon)
    // must derive the same key for the same job.
    let dir = tmp_dir("fpmatch");
    let plan = tiny_plan();
    let store = Store::open(&dir).unwrap();
    run_sweep_stored(&plan, Some(&store));
    for job in plan.jobs() {
        let nl = job.bench.netlist();
        let exact = TruthTables::simulate(&nl).output_values(&nl);
        let fp = job_fingerprint(
            nl.n_inputs(),
            nl.n_outputs(),
            &exact,
            job.method,
            job.et,
            &job.search,
        );
        assert!(store.contains(fp), "{} {} et={}", job.bench.name, job.method.name(), job.et);
    }
    std::fs::remove_dir_all(&dir).unwrap();
}

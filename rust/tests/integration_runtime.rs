//! PJRT runtime integration: the AOT artifact (JAX + Pallas, compiled
//! via `make artifacts`) must agree bit-for-bit with the rust evaluator.
//! Skips gracefully when artifacts have not been built.

use sxpat::circuit::generators::benchmark_by_name;
use sxpat::circuit::sim::TruthTables;
use sxpat::evaluator::pack::widen_to_pool;
use sxpat::evaluator::rust_eval::evaluate_batch;
use sxpat::runtime::{find_artifacts_dir, Runtime};
use sxpat::template::SopParams;
use sxpat::util::Rng;

fn runtime_or_skip() -> Option<Runtime> {
    let dir = find_artifacts_dir()?;
    match Runtime::load(&dir) {
        Ok(rt) => Some(rt),
        Err(e) => panic!("artifacts exist but failed to load: {e:#}"),
    }
}

#[test]
fn artifact_manifest_covers_all_benchmarks() {
    let Some(rt) = runtime_or_skip() else {
        eprintln!("skipping: run `make artifacts` first");
        return;
    };
    for name in ["adder_i4", "mult_i4", "adder_i6", "mult_i6", "adder_i8", "mult_i8"] {
        let g = rt.geometry(name).unwrap_or_else(|| panic!("missing {name}"));
        let bench = benchmark_by_name(name).unwrap();
        assert_eq!(g.n, bench.n_inputs(), "{name}");
        assert_eq!(g.m, bench.n_outputs(), "{name}");
    }
}

#[test]
fn pjrt_matches_rust_evaluator_exactly() {
    let Some(rt) = runtime_or_skip() else {
        eprintln!("skipping: run `make artifacts` first");
        return;
    };
    for name in ["adder_i4", "mult_i6", "mult_i8"] {
        let bench = benchmark_by_name(name).unwrap();
        let nl = bench.netlist();
        let exact = TruthTables::simulate(&nl).output_values(&nl);
        let g = rt.geometry(name).unwrap().clone();
        let mut rng = Rng::seed_from(0xA5A5 ^ g.n as u64);
        let batch: Vec<SopParams> = (0..40)
            .map(|_| SopParams::random(&mut rng, g.n, g.m, g.t, 0.35, 0.3))
            .collect();
        let via_pjrt = rt.evaluate_batch(name, &batch, &exact).unwrap();
        let via_rust = evaluate_batch(&batch, &exact);
        for (i, (a, b)) in via_pjrt.iter().zip(&via_rust).enumerate() {
            assert_eq!(a.max_err, b.max_err, "{name}[{i}] max");
            assert!((a.mean_err - b.mean_err).abs() < 1e-3, "{name}[{i}] mean");
            assert_eq!(a.values, b.values, "{name}[{i}] values");
        }
    }
}

#[test]
fn pjrt_batches_larger_than_artifact_b_are_chunked() {
    let Some(rt) = runtime_or_skip() else {
        eprintln!("skipping: run `make artifacts` first");
        return;
    };
    let name = "adder_i4";
    let bench = benchmark_by_name(name).unwrap();
    let nl = bench.netlist();
    let exact = TruthTables::simulate(&nl).output_values(&nl);
    let g = rt.geometry(name).unwrap().clone();
    let mut rng = Rng::seed_from(17);
    let batch: Vec<SopParams> = (0..g.b + 37)
        .map(|_| SopParams::random(&mut rng, g.n, g.m, g.t, 0.4, 0.3))
        .collect();
    let via_pjrt = rt.evaluate_batch(name, &batch, &exact).unwrap();
    assert_eq!(via_pjrt.len(), batch.len());
    let via_rust = evaluate_batch(&batch, &exact);
    for (a, b) in via_pjrt.iter().zip(&via_rust) {
        assert_eq!(a.values, b.values);
    }
}

#[test]
fn widen_then_pjrt_matches_narrow_rust_eval() {
    // The search uses small pools; the artifact uses T=16. Widening must
    // not change semantics through the PJRT path.
    let Some(rt) = runtime_or_skip() else {
        eprintln!("skipping: run `make artifacts` first");
        return;
    };
    let name = "mult_i4";
    let bench = benchmark_by_name(name).unwrap();
    let nl = bench.netlist();
    let exact = TruthTables::simulate(&nl).output_values(&nl);
    let g = rt.geometry(name).unwrap().clone();
    let mut rng = Rng::seed_from(23);
    let narrow: Vec<SopParams> = (0..16)
        .map(|_| SopParams::random(&mut rng, g.n, g.m, 6, 0.4, 0.3))
        .collect();
    let widened: Vec<SopParams> =
        narrow.iter().map(|p| widen_to_pool(p, g.t)).collect();
    let via_pjrt = rt.evaluate_batch(name, &widened, &exact).unwrap();
    let via_rust = evaluate_batch(&narrow, &exact);
    for (a, b) in via_pjrt.iter().zip(&via_rust) {
        assert_eq!(a.values, b.values);
        assert_eq!(a.max_err, b.max_err);
    }
}

//! Cross-module integration: full synthesis pipelines on the paper's
//! benchmarks, methods compared end-to-end.

use sxpat::baselines::{mecals, muscat, random_sound_baseline};
use sxpat::circuit::generators::{benchmark_by_name, PAPER_BENCHMARKS};
use sxpat::circuit::sim::{is_sound, TruthTables};
use sxpat::circuit::verilog::{parse_verilog, write_verilog};
use sxpat::coordinator::{run_sweep, Method, SweepPlan};
use sxpat::search::{search_shared, search_xpat, SearchConfig};
use sxpat::synth::synthesize_area;

fn quick_cfg() -> SearchConfig {
    SearchConfig {
        pool: 8,
        solutions_per_cell: 2,
        max_sat_cells: 3,
        conflict_budget: Some(100_000),
        time_budget_ms: 60_000,
        ..Default::default()
    }
}

#[test]
fn shared_pipeline_end_to_end_on_i4_benchmarks() {
    for name in ["adder_i4", "mult_i4"] {
        let bench = benchmark_by_name(name).unwrap();
        let nl = bench.netlist();
        let exact = TruthTables::simulate(&nl).output_values(&nl);
        let et = bench.fig4_et();
        let out = search_shared(&nl, et, &quick_cfg());
        let best = out.best().unwrap_or_else(|| panic!("{name}: no solution"));
        // Soundness, extraction round-trip, verilog round-trip, area sanity.
        assert!(is_sound(&exact, &best.params.output_values(), et));
        let approx_nl = best.params.to_netlist("approx");
        let reparsed = parse_verilog(&write_verilog(&approx_nl)).unwrap();
        let tt = TruthTables::simulate(&reparsed);
        assert_eq!(tt.output_values(&reparsed), best.params.output_values());
        assert!(best.area <= synthesize_area(&nl));
    }
}

#[test]
fn paper_headline_shared_wins_or_ties_on_fig4_grid() {
    // Fig. 4 take-away (2): SHARED produces circuits with lower area
    // than the other methods (we allow ties at this tiny scale).
    for name in ["adder_i4", "mult_i4"] {
        let bench = benchmark_by_name(name).unwrap();
        let nl = bench.netlist();
        let et = bench.fig4_et();
        let mut cfg = quick_cfg();
        cfg.max_sat_cells = 12;
        cfg.solutions_per_cell = 3;
        let shared = search_shared(&nl, et, &cfg).best().unwrap().area;
        let xpat = search_xpat(&nl, et, &cfg).best().unwrap().area;
        let mus = muscat(&nl, et).area;
        let mec = mecals(&nl, et).area;
        assert!(
            shared <= xpat + 1e-9 && shared <= mus + 1e-9 && shared <= mec + 1e-9,
            "{name}: shared {shared} vs xpat {xpat}, muscat {mus}, mecals {mec}"
        );
    }
}

#[test]
fn et_slack_buys_area_for_every_method() {
    // Greedy baselines are not strictly ET-monotone (their local optima
    // shift), but the largest-ET result must beat both the tightest-ET
    // result and the exact circuit for every method.
    let bench = benchmark_by_name("mult_i4").unwrap();
    let nl = bench.netlist();
    let exact_area = synthesize_area(&nl);
    for method in ["shared", "muscat", "mecals"] {
        let areas: Vec<f64> = bench
            .et_sweep()
            .iter()
            .map(|&et| match method {
                "shared" => search_shared(&nl, et, &quick_cfg()).best().unwrap().area,
                "muscat" => muscat(&nl, et).area,
                _ => mecals(&nl, et).area,
            })
            .collect();
        let first = areas.first().unwrap();
        let last = areas.last().unwrap();
        assert!(last <= first, "{method}: {areas:?}");
        assert!(*last < exact_area, "{method}: no saving at max ET: {areas:?}");
        // SHARED (first-SAT over a fixed proxy-ordered lattice) is
        // monotone up to enumeration noise.
        if method == "shared" {
            for w in areas.windows(2) {
                assert!(w[1] <= w[0] + 1.1, "shared wobbled: {areas:?}");
            }
        }
    }
}

#[test]
fn random_baseline_dominated_by_shared() {
    // Fig. 4: the random cloud sits at larger area than SHARED's points.
    let bench = benchmark_by_name("adder_i4").unwrap();
    let nl = bench.netlist();
    let et = bench.fig4_et();
    let mut cfg = quick_cfg();
    cfg.max_sat_cells = 12;
    let best = search_shared(&nl, et, &cfg).best().unwrap().area;
    let random = random_sound_baseline(&nl, et, 100, 8, 1, None);
    assert_eq!(random.len(), 100);
    let min_random = random.first().unwrap().area;
    assert!(
        best <= min_random + 1e-9,
        "SHARED {best} should be <= best random {min_random}"
    );
}

#[test]
fn sweep_grid_produces_finite_sound_areas_on_i4() {
    let plan = SweepPlan {
        benches: vec![benchmark_by_name("adder_i4").unwrap()],
        methods: Method::all_compared().to_vec(),
        ets: None,
        search: quick_cfg(),
        workers: 4,
    };
    let records = run_sweep(&plan);
    assert_eq!(records.len(), 2 * 4); // 2 ETs x 4 methods
    for r in &records {
        assert!(r.area.is_finite(), "{} et={} infinite", r.method.name(), r.et);
        assert!(r.max_err <= r.et);
    }
}

#[test]
fn sweep_with_nested_cell_workers_matches_flat_sweep() {
    // Nested parallelism (jobs × lattice cells) must agree with the flat
    // sweep on the areas it reports.
    let mk = |cell_workers: usize| SweepPlan {
        benches: vec![benchmark_by_name("adder_i4").unwrap()],
        methods: vec![Method::Shared],
        ets: Some(vec![1]),
        search: SearchConfig {
            pool: 5,
            solutions_per_cell: 1,
            max_sat_cells: 2,
            conflict_budget: None,
            time_budget_ms: 120_000,
            cell_workers,
            ..Default::default()
        },
        workers: 2,
    };
    let flat = run_sweep(&mk(1));
    let nested = run_sweep(&mk(2));
    assert_eq!(flat.len(), nested.len());
    for (a, b) in flat.iter().zip(&nested) {
        assert!(
            (a.area - b.area).abs() < 1e-9,
            "{} et={}: flat {} vs nested {}",
            a.bench,
            a.et,
            a.area,
            b.area
        );
    }
}

#[test]
fn benchmark_verilog_files_round_trip() {
    for b in &PAPER_BENCHMARKS {
        let nl = b.netlist();
        let v = write_verilog(&nl);
        let back = parse_verilog(&v).unwrap();
        let a = TruthTables::simulate(&nl).output_values(&nl);
        let c = TruthTables::simulate(&back).output_values(&back);
        assert_eq!(a, c, "{}", b.name);
    }
}

#[test]
fn i6_shared_search_completes_with_sound_result() {
    // One bigger geometry to prove the ∀-expansion scales past i4.
    let bench = benchmark_by_name("adder_i6").unwrap();
    let nl = bench.netlist();
    let exact = TruthTables::simulate(&nl).output_values(&nl);
    let et = 8;
    let mut cfg = quick_cfg();
    cfg.max_sat_cells = 2;
    cfg.solutions_per_cell = 1;
    let out = search_shared(&nl, et, &cfg);
    let best = out.best().expect("i6 search must find a solution");
    assert!(is_sound(&exact, &best.params.output_values(), et));
    assert!(best.area < synthesize_area(&nl));
}

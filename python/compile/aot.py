"""AOT lowering: L2 evaluator -> HLO *text* artifacts for the rust runtime.

Interchange format is HLO text, NOT `lowered.compile().serialize()`:
jax >= 0.5 emits HloModuleProtos with 64-bit instruction ids which the xla
crate's xla_extension 0.5.1 rejects (`proto.id() <= INT_MAX`); the text
parser reassigns ids and round-trips cleanly. See /opt/xla-example/README.md.

Usage:  cd python && python -m compile.aot --out-dir ../artifacts

Emits one `sop_eval_<bench>.hlo.txt` per geometry plus `manifest.json`
describing the shape contract the rust side (runtime/artifacts.rs) checks.
"""

from __future__ import annotations

import argparse
import json
import pathlib

import jax
from jax._src.lib import xla_client as xc

from compile.model import GEOMETRIES, evaluate_batch, example_args


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (return_tuple for rust)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_geometry(geom) -> str:
    fn = evaluate_batch(geom)
    lowered = jax.jit(fn).lower(*example_args(geom))
    return to_hlo_text(lowered)


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--only", default=None,
                    help="lower a single geometry by name (e.g. adder_i4)")
    args = ap.parse_args()

    out_dir = pathlib.Path(args.out_dir)
    out_dir.mkdir(parents=True, exist_ok=True)

    manifest = {}
    for geom in GEOMETRIES:
        if args.only and geom.name != args.only:
            continue
        text = lower_geometry(geom)
        path = out_dir / f"sop_eval_{geom.name}.hlo.txt"
        path.write_text(text)
        manifest[geom.name] = {
            "file": path.name,
            "n": geom.n,
            "m": geom.m,
            "t": geom.t,
            "b": geom.b,
            "npoints": geom.npoints,
        }
        print(f"wrote {path} ({len(text)} chars)")

    manifest_path = out_dir / "manifest.json"
    # Merge so `--only` refreshes one entry without dropping the rest.
    if args.only and manifest_path.exists():
        old = json.loads(manifest_path.read_text())
        old.update(manifest)
        manifest = old
    manifest_path.write_text(json.dumps(manifest, indent=2, sort_keys=True))
    print(f"wrote {manifest_path}")


if __name__ == "__main__":
    main()

"""Pure-jnp oracle for the sop_eval Pallas kernel.

Implements eq. (1)/(2) of the paper with direct boolean semantics — no
affine tricks, no pallas — so any disagreement with kernels/sop_eval.py
points at the kernel. Kept deliberately naive.
"""

from __future__ import annotations

import jax.numpy as jnp


def truth_table(n: int) -> jnp.ndarray:
    """[2^n, n] {0,1} f32; row x = bits of integer x, LSB in column 0."""
    x = jnp.arange(2**n, dtype=jnp.uint32)
    bits = (x[:, None] >> jnp.arange(n, dtype=jnp.uint32)[None, :]) & 1
    return bits.astype(jnp.float32)


def sop_eval_ref(use_mask, neg_mask, out_sel, out_const, exact):
    """Reference semantics of sop_eval; same signature and returns.

    For every input point x and candidate b:
      lit[j]  = X[x,j] XOR neg[b,t,j]
      prod[t] = AND over {j : use[b,t,j]=1} of lit[j]   (empty AND = 1)
      bit[i]  = OR  over {t : out_sel[b,i,t]=1} of prod[t], OR out_const[b,i]
      V       = sum_i bit[i] * 2^i
    """
    b, t, n = use_mask.shape
    m = out_sel.shape[1]
    x = truth_table(n)  # [N, n]

    lit = jnp.abs(x[None, None, :, :] - neg_mask[:, :, None, :])  # XOR
    # A selected literal that is 0 kills the product; unselected -> treat as 1.
    lit_or_one = jnp.where(use_mask[:, :, None, :] > 0.5, lit, 1.0)
    prod = jnp.prod(lit_or_one, axis=3)  # [B, T, N]

    fired = jnp.einsum("bit,btx->bix", out_sel, prod)
    bit = jnp.maximum((fired > 0.5).astype(jnp.float32),
                      out_const[:, :, None])  # [B, m, N]

    weights = (2.0 ** jnp.arange(m, dtype=jnp.float32))[None, :, None]
    val = jnp.sum(bit * weights, axis=1)  # [B, N]
    err = jnp.abs(val - exact[None, :])
    return jnp.max(err, axis=1), jnp.mean(err, axis=1), val

"""L1 Pallas kernel: batched SOP-template evaluation over a full truth table.

The paper's hot numeric path is exhaustive error evaluation of candidate
sum-of-products (SOP) template instantiations: given B candidate parameter
assignments for a template with T products over n inputs and m outputs,
compute each candidate's output value on *all* 2^n input assignments and
reduce to max/mean error distance against the exact circuit.

TPU-idiomatic formulation (see DESIGN.md §Hardware-Adaptation): instead of
evaluating AND/OR trees per input point, we encode each product's
"violation count" affinely so the inner loop is a matmul shaped for the MXU:

    fail_j      = use_j AND (X_j == neg_j)              (literal selected, 0)
    viol[b,t,x] = c[b,t] + sum_j w[b,t,j] * X[x,j]
      with  c = sum_j use*(1-neg),  w = use*(2*neg - 1)
    P[b,t,x]    = viol < 0.5                            (product fires)
    acc[b,i,x]  = sum_t out_sel[b,i,t] * P[b,t,x]       (second matmul)
    bit[b,i,x]  = (acc > 0.5) OR out_const[b,i]
    V[b,x]      = sum_i bit * 2^i
    err         = |V - exact[x]|  ->  max_x, mean_x

Both heavy contractions ((B*T, n) x (n, 2^n) and per-b (m, T) x (T, 2^n))
stream through VMEM once; the truth table X is a compile-time constant that
stays resident. interpret=True throughout: real-TPU lowering would emit a
Mosaic custom-call the CPU PJRT plugin cannot execute.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Batch tile: candidates processed per grid step. 64 keeps the largest
# geometry's working set (i8: T=16, 2^n=256) around 2 MiB of VMEM.
DEFAULT_BLOCK_B = 64


def _truth_table(n: int) -> jnp.ndarray:
    """[2^n, n] float32 matrix of all input assignments; column j is in_j.

    Row x encodes the integer x with bit 0 in column 0 (LSB-first), matching
    the rust evaluator's packing (rust/src/evaluator/pack.rs).
    """
    x = jnp.arange(2**n, dtype=jnp.uint32)
    bits = (x[:, None] >> jnp.arange(n, dtype=jnp.uint32)[None, :]) & 1
    return bits.astype(jnp.float32)


def _sop_eval_kernel(
    w_ref,          # [Bb, T, n]  affine literal weights
    c_ref,          # [Bb, T]     affine literal constants
    out_sel_ref,    # [Bb, m, T]  product -> sum selection
    out_const_ref,  # [Bb, m]     output forced to constant 1
    exact_ref,      # [N]         exact integer value per input point
    xt_ref,         # [n, N]      truth table, transposed (constant input)
    max_ref,        # [Bb]        out: max error distance
    mean_ref,       # [Bb]        out: mean error distance
    val_ref,        # [Bb, N]     out: approximate output values
):
    w = w_ref[...]
    c = c_ref[...]
    out_sel = out_sel_ref[...]
    out_const = out_const_ref[...]
    exact = exact_ref[...]
    xt = xt_ref[...]

    bb, t, n = w.shape
    m = out_sel.shape[1]
    npoints = xt.shape[1]

    # First matmul: violation counts for every (candidate, product, point).
    viol = jnp.dot(w.reshape(bb * t, n), xt) + c.reshape(bb * t, 1)
    prod = (viol < 0.5).astype(jnp.float32).reshape(bb, t, npoints)

    # Second (batched) matmul: how many selected products fire per output.
    acc = jax.lax.dot_general(
        out_sel, prod, dimension_numbers=(((2,), (1,)), ((0,), (0,)))
    )  # [Bb, m, N]
    bit = jnp.maximum(
        (acc > 0.5).astype(jnp.float32), out_const[:, :, None]
    )

    # Integer interpretation of the output bus (LSB-first) and error.
    weights = (2.0 ** jnp.arange(m, dtype=jnp.float32))[None, :, None]
    val = jnp.sum(bit * weights, axis=1)  # [Bb, N]
    err = jnp.abs(val - exact[None, :])

    max_ref[...] = jnp.max(err, axis=1)
    mean_ref[...] = jnp.mean(err, axis=1)
    val_ref[...] = val


@functools.partial(jax.jit, static_argnames=("block_b",))
def sop_eval(use_mask, neg_mask, out_sel, out_const, exact,
             block_b: int = DEFAULT_BLOCK_B):
    """Evaluate a batch of SOP template instantiations exhaustively.

    Args:
      use_mask:  [B, T, n] {0,1} f32 — literal j participates in product t.
      neg_mask:  [B, T, n] {0,1} f32 — literal appears negated.
      out_sel:   [B, m, T] {0,1} f32 — product t feeds output sum i.
      out_const: [B, m]    {0,1} f32 — output i is the constant 1.
      exact:     [2^n]     f32      — exact circuit's integer output value.

    Returns:
      (max_err [B], mean_err [B], values [B, 2^n]) — error distances and the
      approximate integer output value per input point (LSB-first input
      ordering; see _truth_table).

    Note: a product with *no* selected literal is the constant 1 (empty AND),
    and an output with no selected product and out_const=0 is the constant 0
    (empty OR) — matching eq. (1)/(2) of the paper.
    """
    b, t, n = use_mask.shape
    m = out_sel.shape[1]
    if b % block_b != 0:
        raise ValueError(f"batch {b} must be a multiple of block_b {block_b}")

    # Affine encoding of "selected literal evaluates to 0" (see module doc).
    w = use_mask * (2.0 * neg_mask - 1.0)
    c = jnp.sum(use_mask * (1.0 - neg_mask), axis=2)
    xt = _truth_table(n).T  # [n, 2^n], compile-time constant

    npoints = 2**n
    grid = (b // block_b,)
    kernel = pl.pallas_call(
        _sop_eval_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_b, t, n), lambda i: (i, 0, 0)),
            pl.BlockSpec((block_b, t), lambda i: (i, 0)),
            pl.BlockSpec((block_b, m, t), lambda i: (i, 0, 0)),
            pl.BlockSpec((block_b, m), lambda i: (i, 0)),
            pl.BlockSpec((npoints,), lambda i: (0,)),
            pl.BlockSpec((n, npoints), lambda i: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((block_b,), lambda i: (i,)),
            pl.BlockSpec((block_b,), lambda i: (i,)),
            pl.BlockSpec((block_b, npoints), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b,), jnp.float32),
            jax.ShapeDtypeStruct((b,), jnp.float32),
            jax.ShapeDtypeStruct((b, npoints), jnp.float32),
        ],
        interpret=True,
    )
    return tuple(kernel(w, c, out_sel, out_const, exact, xt))

"""L2 JAX model: the batched exhaustive SOP error evaluator.

This is the compute graph the rust coordinator executes via PJRT. It wraps
the L1 Pallas kernel (kernels/sop_eval.py) with the parameter packing the
coordinator uses and fixes one geometry per AOT artifact:

    geometry = (n inputs, m outputs, T products, B batch)

The benchmark geometries mirror the paper's evaluation set (adders and
multipliers at i4/i6/i8 — §IV): one artifact per geometry, any circuit with
that shape reuses it because the exact values arrive as a runtime input.

Python runs only at build time (`make artifacts`); the serving path is rust.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from compile.kernels.sop_eval import sop_eval


@dataclasses.dataclass(frozen=True)
class Geometry:
    """One AOT artifact's shape contract; mirrored in rust/src/runtime."""

    name: str
    n: int  # circuit inputs
    m: int  # circuit outputs
    t: int  # template product pool size (max PIT)
    b: int  # candidate batch size

    @property
    def npoints(self) -> int:
        return 2**self.n


def _adder_geometry(bits: int, t: int, b: int) -> Geometry:
    # bits-bit + bits-bit ripple-carry adder: 2*bits inputs, bits+1 outputs.
    return Geometry(f"adder_i{2 * bits}", 2 * bits, bits + 1, t, b)


def _mult_geometry(bits: int, t: int, b: int) -> Geometry:
    # bits x bits array multiplier: 2*bits inputs, 2*bits outputs.
    return Geometry(f"mult_i{2 * bits}", 2 * bits, 2 * bits, t, b)


# The paper evaluates bitwidths 2/3/4 (benchmarks i4/i6/i8). T is sized so
# the shared template can express every circuit the search sweeps (PIT <= T);
# B=256 amortises PJRT dispatch without blowing VMEM (DESIGN.md §7).
GEOMETRIES: tuple[Geometry, ...] = tuple(
    g
    for bits in (2, 3, 4)
    for g in (_adder_geometry(bits, t=16, b=256),
              _mult_geometry(bits, t=16, b=256))
)


def evaluate_batch(geom: Geometry):
    """Returns the jax fn evaluating B candidates of geometry `geom`.

    Signature (all f32):
      use_mask [B,T,n], neg_mask [B,T,n], out_sel [B,m,T], out_const [B,m],
      exact [2^n]  ->  (max_err [B], mean_err [B], values [B, 2^n])
    """

    def fn(use_mask, neg_mask, out_sel, out_const, exact):
        return sop_eval(use_mask, neg_mask, out_sel, out_const, exact)

    return fn


def example_args(geom: Geometry):
    """ShapeDtypeStructs for AOT lowering of evaluate_batch(geom)."""
    f32 = jnp.float32
    return (
        jax.ShapeDtypeStruct((geom.b, geom.t, geom.n), f32),
        jax.ShapeDtypeStruct((geom.b, geom.t, geom.n), f32),
        jax.ShapeDtypeStruct((geom.b, geom.m, geom.t), f32),
        jax.ShapeDtypeStruct((geom.b, geom.m), f32),
        jax.ShapeDtypeStruct((geom.npoints,), f32),
    )

"""L2 model + AOT lowering tests: geometry contract and HLO-text emission."""

from __future__ import annotations

import numpy as np
import pytest

import jax

from compile.model import GEOMETRIES, Geometry, evaluate_batch, example_args
from compile.aot import lower_geometry, to_hlo_text


def test_geometry_set_matches_paper():
    names = {g.name for g in GEOMETRIES}
    assert names == {
        "adder_i4", "mult_i4", "adder_i6", "mult_i6", "adder_i8", "mult_i8",
    }


@pytest.mark.parametrize("geom", GEOMETRIES, ids=lambda g: g.name)
def test_geometry_shapes(geom: Geometry):
    # adder_iN: N inputs, N/2+1 outputs; mult_iN: N inputs, N outputs.
    bits = geom.n // 2
    if geom.name.startswith("adder"):
        assert geom.m == bits + 1
    else:
        assert geom.m == 2 * bits
    assert geom.npoints == 2**geom.n
    assert geom.b % 64 == 0  # must tile by the kernel block


def test_evaluate_batch_runs_smallest_geometry():
    geom = next(g for g in GEOMETRIES if g.name == "adder_i4")
    rng = np.random.default_rng(7)
    fn = evaluate_batch(geom)
    use = (rng.random((geom.b, geom.t, geom.n)) < 0.5).astype(np.float32)
    neg = (rng.random((geom.b, geom.t, geom.n)) < 0.5).astype(np.float32)
    sel = (rng.random((geom.b, geom.m, geom.t)) < 0.4).astype(np.float32)
    const = np.zeros((geom.b, geom.m), np.float32)
    exact = rng.integers(0, 2**geom.m, geom.npoints).astype(np.float32)
    mx, mean, val = fn(use, neg, sel, const, exact)
    assert mx.shape == (geom.b,)
    assert mean.shape == (geom.b,)
    assert val.shape == (geom.b, geom.npoints)
    assert np.all(np.asarray(mx) >= np.asarray(mean) - 1e-5)


def test_hlo_text_emission_smallest_geometry():
    geom = next(g for g in GEOMETRIES if g.name == "adder_i4")
    text = lower_geometry(geom)
    assert "ENTRY" in text
    assert "HloModule" in text
    # Five runtime parameters (truth table is folded in as a constant).
    assert text.count("parameter(") >= 5


def test_hlo_text_is_parseable_by_xla_runtime():
    # Round-trip the text through the same xla_client the rust side embeds.
    from jax._src.lib import xla_client as xc

    geom = next(g for g in GEOMETRIES if g.name == "adder_i4")
    fn = evaluate_batch(geom)
    lowered = jax.jit(fn).lower(*example_args(geom))
    text = to_hlo_text(lowered)
    assert len(text) > 100
    assert "f32[256,16,4]" in text  # B,T,n parameter shape is baked in

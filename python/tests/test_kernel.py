"""Kernel-vs-reference: the core L1 correctness signal.

hypothesis sweeps geometries and random {0,1} parameter tensors; every case
asserts the Pallas kernel (interpret=True) matches the pure-jnp oracle in
ref.py bit-for-bit (all quantities are small integers in f32, so we use
exact comparison via assert_allclose atol=0).
"""

from __future__ import annotations

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.ref import sop_eval_ref, truth_table
from compile.kernels.sop_eval import sop_eval, _truth_table


def _rand_case(rng, b, t, n, m):
    use = (rng.random((b, t, n)) < 0.5).astype(np.float32)
    neg = (rng.random((b, t, n)) < 0.5).astype(np.float32)
    sel = (rng.random((b, m, t)) < 0.4).astype(np.float32)
    const = (rng.random((b, m)) < 0.1).astype(np.float32)
    exact = rng.integers(0, 2**m, size=2**n).astype(np.float32)
    return use, neg, sel, const, exact


def _assert_matches(use, neg, sel, const, exact, block_b):
    got = sop_eval(use, neg, sel, const, exact, block_b=block_b)
    want = sop_eval_ref(use, neg, sel, const, exact)
    for g, w, name in zip(got, want, ("max", "mean", "values")):
        np.testing.assert_allclose(np.asarray(g), np.asarray(w), atol=1e-5,
                                   err_msg=f"mismatch in {name}")


@settings(max_examples=25, deadline=None)
@given(
    n=st.integers(2, 6),
    m=st.integers(1, 6),
    t=st.integers(1, 8),
    seed=st.integers(0, 2**31 - 1),
)
def test_kernel_matches_ref_hypothesis(n, m, t, seed):
    rng = np.random.default_rng(seed)
    b = 8
    _assert_matches(*_rand_case(rng, b, t, n, m), block_b=4)


@pytest.mark.parametrize("geom_idx", range(6))
def test_kernel_matches_ref_paper_geometries(geom_idx):
    from compile.model import GEOMETRIES

    g = GEOMETRIES[geom_idx]
    rng = np.random.default_rng(42 + geom_idx)
    _assert_matches(*_rand_case(rng, g.b, g.t, g.n, g.m), block_b=64)


def test_truth_tables_agree():
    for n in range(1, 9):
        np.testing.assert_array_equal(
            np.asarray(_truth_table(n)), np.asarray(truth_table(n))
        )


def test_empty_product_is_constant_one():
    # A product with no selected literals must fire on every input (empty
    # AND), so an output selecting only it is the constant 1 -> value 2^i.
    b, t, n, m = 4, 2, 3, 2
    use = np.zeros((b, t, n), np.float32)
    neg = np.zeros((b, t, n), np.float32)
    sel = np.zeros((b, m, t), np.float32)
    sel[:, 1, 0] = 1.0  # out_1 = Prod_0 = const 1
    const = np.zeros((b, m), np.float32)
    exact = np.zeros(2**n, np.float32)
    mx, mean, val = sop_eval(use, neg, sel, const, exact, block_b=2)
    np.testing.assert_array_equal(np.asarray(val), np.full((b, 2**n), 2.0))
    np.testing.assert_array_equal(np.asarray(mx), np.full(b, 2.0))


def test_empty_output_is_constant_zero():
    b, t, n, m = 2, 3, 4, 3
    use = np.ones((b, t, n), np.float32)
    neg = np.zeros((b, t, n), np.float32)
    sel = np.zeros((b, m, t), np.float32)  # nothing selected anywhere
    const = np.zeros((b, m), np.float32)
    exact = np.arange(2**n, dtype=np.float32) % (2**m)
    mx, mean, val = sop_eval(use, neg, sel, const, exact, block_b=2)
    np.testing.assert_array_equal(np.asarray(val), np.zeros((b, 2**n)))
    np.testing.assert_array_equal(
        np.asarray(mx), np.max(np.abs(exact)) * np.ones(b)
    )


def test_out_const_forces_one():
    b, t, n, m = 2, 2, 2, 2
    use = np.ones((b, t, n), np.float32)
    neg = np.zeros((b, t, n), np.float32)
    sel = np.zeros((b, m, t), np.float32)
    const = np.ones((b, m), np.float32)
    exact = np.zeros(2**n, np.float32)
    _, _, val = sop_eval(use, neg, sel, const, exact, block_b=2)
    np.testing.assert_array_equal(np.asarray(val), np.full((b, 2**n), 3.0))


def test_single_literal_identity():
    # out_0 = in_0: product selects in_0 positively; error vs exact=bit0 is 0.
    b, t, n, m = 2, 1, 3, 1
    use = np.zeros((b, t, n), np.float32)
    use[:, 0, 0] = 1.0
    neg = np.zeros((b, t, n), np.float32)
    sel = np.ones((b, m, t), np.float32)
    const = np.zeros((b, m), np.float32)
    exact = (np.arange(2**n) & 1).astype(np.float32)
    mx, mean, val = sop_eval(use, neg, sel, const, exact, block_b=2)
    np.testing.assert_array_equal(np.asarray(mx), np.zeros(b))


def test_negated_literal():
    # out_0 = NOT in_1 over n=2 inputs.
    b, t, n, m = 2, 1, 2, 1
    use = np.zeros((b, t, n), np.float32)
    use[:, 0, 1] = 1.0
    neg = np.zeros((b, t, n), np.float32)
    neg[:, 0, 1] = 1.0
    sel = np.ones((b, m, t), np.float32)
    const = np.zeros((b, m), np.float32)
    exact = np.zeros(4, np.float32)
    _, _, val = sop_eval(use, neg, sel, const, exact, block_b=2)
    # inputs x = 0,1,2,3 -> in_1 = 0,0,1,1 -> NOT in_1 = 1,1,0,0
    np.testing.assert_array_equal(
        np.asarray(val), np.tile([1.0, 1.0, 0.0, 0.0], (b, 1))
    )


def test_block_b_mismatch_raises():
    rng = np.random.default_rng(0)
    case = _rand_case(rng, 6, 2, 3, 2)
    with pytest.raises(ValueError):
        sop_eval(*case, block_b=4)
